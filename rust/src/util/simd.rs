//! Runtime-dispatched SIMD kernels for the hot loops (`std::arch`
//! only — no deps). Three tiers: explicit AVX2 on x86_64, NEON on
//! aarch64, and a portable scalar fallback that doubles as the
//! bit-exact reference.
//!
//! Determinism (DESIGN.md §3): every vector kernel keeps the *scalar*
//! reduction semantics — [`LANES`] independent accumulators where lane
//! `l` sums elements `l, l + LANES, ...`, lanes folded in ascending
//! lane order, the `len % LANES` tail added last — and never uses a
//! fused multiply-add (an FMA skips the intermediate rounding the
//! scalar path performs). One AVX2 register *is* the 8 scalar lanes;
//! on NEON two 4-lane registers hold lanes 0–3 and 4–7 and fold in
//! lane order. Results are therefore bit-identical across tiers, ISAs
//! and thread counts — which is what lets CI run the whole suite under
//! `LOTION_SIMD=scalar` against goldens produced under `auto`.
//!
//! Tier resolution mirrors the pool's thread knob: an explicit
//! [`set_global_simd`] (the CLI's `--simd`) beats the `LOTION_SIMD`
//! env var, which beats runtime feature detection; a requested tier
//! the CPU cannot run falls back to scalar. Larger kernels (the
//! blocked matmuls, the quant block loops) dispatch through
//! [`simd_kernel!`], which compiles one shared `#[inline(always)]`
//! body per tier inside a `#[target_feature]` clone — same Rust code,
//! same fold order, wider registers.

use std::sync::atomic::{AtomicU8, Ordering};

/// Independent accumulator lanes in the reduction helpers. Wide enough
/// to fill one AVX register (or two NEON registers) of `f32`s and to
/// break the serial FP dependency chain; never derived from the
/// machine, so the reduction order is portable.
pub const LANES: usize = 8;

/// A kernel instruction tier. `Scalar` is the reference everything
/// else must match bitwise; `Avx2` implies FMA availability (the
/// matmul clones enable both, though no kernel contracts into FMAs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum SimdTier {
    Scalar = 0,
    Avx2 = 1,
    Neon = 2,
}

impl SimdTier {
    pub fn name(self) -> &'static str {
        match self {
            SimdTier::Scalar => "scalar",
            SimdTier::Avx2 => "avx2",
            SimdTier::Neon => "neon",
        }
    }

    /// Parse a `--simd` / `LOTION_SIMD` value; `None` means `auto`
    /// (resolve by detection at dispatch time).
    pub fn parse(s: &str) -> anyhow::Result<Option<SimdTier>> {
        Ok(match s {
            "auto" => None,
            "scalar" => Some(SimdTier::Scalar),
            "avx2" => Some(SimdTier::Avx2),
            "neon" => Some(SimdTier::Neon),
            other => anyhow::bail!("unknown SIMD tier {other:?} (expected auto|scalar|avx2|neon)"),
        })
    }

    /// Whether this tier can run on the current CPU. Forcing an
    /// unsupported tier is not an error — [`active_tier`] clamps it to
    /// scalar — so a config written on one machine runs anywhere.
    pub fn supported(self) -> bool {
        match self {
            SimdTier::Scalar => true,
            SimdTier::Avx2 => avx2_available(),
            // NEON is baseline on aarch64
            SimdTier::Neon => cfg!(target_arch = "aarch64"),
        }
    }
}

fn avx2_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Sentinel for "no tier stored" in the atomic slots below.
const TIER_UNSET: u8 = u8::MAX;

/// The explicit process-wide tier (`--simd`); `TIER_UNSET` = never
/// set, resolve auto per call. Kept separate from the lazily-resolved
/// auto value (same reasoning as the pool's `EXPLICIT_THREADS`): an
/// explicit setting must win no matter when the first kernel ran.
static EXPLICIT_TIER: AtomicU8 = AtomicU8::new(TIER_UNSET);

/// Cached auto tier (`LOTION_SIMD` / detection), `TIER_UNSET` = not
/// resolved yet. Detection is process-constant, so one resolution is
/// enough; caching it apart from [`EXPLICIT_TIER`] means it can never
/// shadow an explicit setting.
static AUTO_TIER: AtomicU8 = AtomicU8::new(TIER_UNSET);

fn tier_from_u8(v: u8) -> Option<SimdTier> {
    match v {
        0 => Some(SimdTier::Scalar),
        1 => Some(SimdTier::Avx2),
        2 => Some(SimdTier::Neon),
        _ => None,
    }
}

/// Install the process-wide tier used by every dispatched kernel:
/// `None` means auto (`LOTION_SIMD` / detection, re-resolved on use),
/// `Some(tier)` overrides auto from then on. The CLI calls this with
/// the `--simd` value.
pub fn set_global_simd(tier: Option<SimdTier>) {
    EXPLICIT_TIER.store(tier.map(|t| t as u8).unwrap_or(TIER_UNSET), Ordering::Relaxed);
}

/// The `LOTION_SIMD` environment override (unset/`auto`/garbage =
/// auto-detect), mirroring `LOTION_THREADS`.
pub fn env_simd() -> Option<SimdTier> {
    std::env::var("LOTION_SIMD").ok().and_then(|v| SimdTier::parse(v.trim()).ok().flatten())
}

/// The best tier runtime detection finds on this CPU.
pub fn detect_tier() -> SimdTier {
    if SimdTier::Avx2.supported() {
        SimdTier::Avx2
    } else if SimdTier::Neon.supported() {
        SimdTier::Neon
    } else {
        SimdTier::Scalar
    }
}

fn clamp_supported(t: SimdTier) -> SimdTier {
    if t.supported() {
        t
    } else {
        SimdTier::Scalar
    }
}

/// Resolve the tier dispatched kernels run at. Precedence: explicit
/// [`set_global_simd`] > `LOTION_SIMD` > detection; unsupported
/// requests clamp to scalar. Hot kernels hoist this once per parallel
/// region rather than per element — the call is two relaxed atomic
/// loads, but hoisting also pins one tier per kernel invocation.
#[inline]
pub fn active_tier() -> SimdTier {
    if let Some(t) = tier_from_u8(EXPLICIT_TIER.load(Ordering::Relaxed)) {
        return clamp_supported(t);
    }
    if let Some(t) = tier_from_u8(AUTO_TIER.load(Ordering::Relaxed)) {
        return t;
    }
    let resolved = clamp_supported(env_simd().unwrap_or_else(detect_tier));
    AUTO_TIER.store(resolved as u8, Ordering::Relaxed);
    resolved
}

/// Define a tier-dispatched kernel: `$name(tier, args...)` runs the
/// shared `#[inline(always)]` `$body` either directly (scalar) or from
/// inside a `#[target_feature]` clone, so the *same* Rust code — same
/// operation order, same fold order — is compiled once per ISA tier
/// and the autovectorizer may widen it without changing results (LLVM
/// never contracts `a * b + c` into an FMA unless asked to). Callers
/// hoist [`active_tier`] once per parallel region and pass it down;
/// passing the tier explicitly is also what lets the parity tests
/// force tiers without touching the process-wide knob. Passing an
/// unsupported tier is undefined behavior — route through
/// [`active_tier`] (which clamps) or check [`SimdTier::supported`].
#[macro_export]
macro_rules! simd_kernel {
    ($vis:vis fn $name:ident(tier $(, $arg:ident : $ty:ty)* $(,)?) $(-> $ret:ty)? = $body:path) => {
        $vis fn $name(tier: $crate::util::simd::SimdTier $(, $arg: $ty)*) $(-> $ret)? {
            #[cfg(target_arch = "x86_64")]
            if tier == $crate::util::simd::SimdTier::Avx2 {
                debug_assert!($crate::util::simd::SimdTier::Avx2.supported());
                #[target_feature(enable = "avx2", enable = "fma")]
                unsafe fn vect($($arg: $ty),*) $(-> $ret)? {
                    $body($($arg),*)
                }
                // SAFETY: the Avx2 tier is only selected once runtime
                // detection confirmed avx2+fma on this CPU.
                return unsafe { vect($($arg),*) };
            }
            #[cfg(target_arch = "aarch64")]
            if tier == $crate::util::simd::SimdTier::Neon {
                #[target_feature(enable = "neon")]
                unsafe fn vect($($arg: $ty),*) $(-> $ret)? {
                    $body($($arg),*)
                }
                // SAFETY: NEON is baseline on aarch64.
                return unsafe { vect($($arg),*) };
            }
            let _ = tier;
            $body($($arg),*)
        }
    };
}

/// `sum_i a[i] * b[i]` with [`LANES`] independent accumulators: lane
/// `l` sums elements `l, l + LANES, ...`; lanes fold in ascending lane
/// order and the `len % LANES` tail is added last. The scalar
/// reference every vector tier must match bitwise.
#[inline(always)]
fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; LANES];
    let mut ach = a.chunks_exact(LANES);
    let mut bch = b.chunks_exact(LANES);
    for (av, bv) in (&mut ach).zip(&mut bch) {
        let av: &[f32; LANES] = av.try_into().unwrap();
        let bv: &[f32; LANES] = bv.try_into().unwrap();
        for l in 0..LANES {
            acc[l] += av[l] * bv[l];
        }
    }
    let mut s = 0.0f32;
    for l in 0..LANES {
        s += acc[l];
    }
    for (av, bv) in ach.remainder().iter().zip(bch.remainder()) {
        s += av * bv;
    }
    s
}

/// `sum_i w[i] * x[i] * x[i]` (a diagonally-weighted squared norm —
/// the linear2 exact-Fisher reduction), with the same fixed lane order
/// as [`dot_scalar`]: each term evaluates as `(w * x) * x`.
#[inline(always)]
fn weighted_sq_scalar(w: &[f32], x: &[f32]) -> f32 {
    debug_assert_eq!(w.len(), x.len());
    let mut acc = [0.0f32; LANES];
    let mut wch = w.chunks_exact(LANES);
    let mut xch = x.chunks_exact(LANES);
    for (wv, xv) in (&mut wch).zip(&mut xch) {
        let wv: &[f32; LANES] = wv.try_into().unwrap();
        let xv: &[f32; LANES] = xv.try_into().unwrap();
        for l in 0..LANES {
            acc[l] += wv[l] * xv[l] * xv[l];
        }
    }
    let mut s = 0.0f32;
    for l in 0..LANES {
        s += acc[l];
    }
    for (wv, xv) in wch.remainder().iter().zip(xch.remainder()) {
        s += wv * xv * xv;
    }
    s
}

/// Dot product at the process-wide [`active_tier`].
#[inline]
pub fn dot_lanes(a: &[f32], b: &[f32]) -> f32 {
    dot_lanes_tier(active_tier(), a, b)
}

/// [`dot_lanes`] at a caller-chosen tier (hoist [`active_tier`] out of
/// inner loops; also the parity tests' entry point). The AVX2/NEON
/// paths are hand intrinsics: one `__m256` (or a `float32x4_t` pair)
/// is exactly the 8 scalar lanes, accumulated with separate mul + add.
#[inline]
pub fn dot_lanes_tier(tier: SimdTier, a: &[f32], b: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    if tier == SimdTier::Avx2 {
        debug_assert!(SimdTier::Avx2.supported());
        // SAFETY: Avx2 is only selected when detection confirmed it.
        return unsafe { x86::dot_avx2(a, b) };
    }
    #[cfg(target_arch = "aarch64")]
    if tier == SimdTier::Neon {
        // SAFETY: NEON is baseline on aarch64.
        return unsafe { neon::dot_neon(a, b) };
    }
    let _ = tier;
    dot_scalar(a, b)
}

/// Weighted squared norm at the process-wide [`active_tier`].
#[inline]
pub fn weighted_sq_lanes(w: &[f32], x: &[f32]) -> f32 {
    weighted_sq_lanes_tier(active_tier(), w, x)
}

/// [`weighted_sq_lanes`] at a caller-chosen tier.
#[inline]
pub fn weighted_sq_lanes_tier(tier: SimdTier, w: &[f32], x: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    if tier == SimdTier::Avx2 {
        debug_assert!(SimdTier::Avx2.supported());
        // SAFETY: Avx2 is only selected when detection confirmed it.
        return unsafe { x86::weighted_sq_avx2(w, x) };
    }
    #[cfg(target_arch = "aarch64")]
    if tier == SimdTier::Neon {
        // SAFETY: NEON is baseline on aarch64.
        return unsafe { neon::weighted_sq_neon(w, x) };
    }
    let _ = tier;
    weighted_sq_scalar(w, x)
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::LANES;
    use std::arch::x86_64::{
        _mm256_add_ps, _mm256_loadu_ps, _mm256_mul_ps, _mm256_setzero_ps, _mm256_storeu_ps,
    };

    /// SAFETY: caller must ensure avx2 is available. Separate mul +
    /// add (never `_mm256_fmadd_ps`): the scalar reference rounds each
    /// product before accumulating, and cross-tier bit-identity is the
    /// contract. Register lane `l` is scalar accumulator lane `l`;
    /// the store-then-sum fold reproduces the ascending lane order.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n8 = a.len() / LANES * LANES;
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i < n8 {
            let av = _mm256_loadu_ps(a.as_ptr().add(i));
            let bv = _mm256_loadu_ps(b.as_ptr().add(i));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(av, bv));
            i += LANES;
        }
        let mut lanes = [0.0f32; LANES];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        let mut s = 0.0f32;
        for l in 0..LANES {
            s += lanes[l];
        }
        for j in n8..a.len() {
            s += a[j] * b[j];
        }
        s
    }

    /// SAFETY: caller must ensure avx2 is available. Term order is
    /// `(w * x) * x`, matching the scalar body.
    #[target_feature(enable = "avx2")]
    pub unsafe fn weighted_sq_avx2(w: &[f32], x: &[f32]) -> f32 {
        debug_assert_eq!(w.len(), x.len());
        let n8 = w.len() / LANES * LANES;
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i < n8 {
            let wv = _mm256_loadu_ps(w.as_ptr().add(i));
            let xv = _mm256_loadu_ps(x.as_ptr().add(i));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(_mm256_mul_ps(wv, xv), xv));
            i += LANES;
        }
        let mut lanes = [0.0f32; LANES];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        let mut s = 0.0f32;
        for l in 0..LANES {
            s += lanes[l];
        }
        for j in n8..w.len() {
            s += w[j] * x[j] * x[j];
        }
        s
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    use super::LANES;
    use std::arch::aarch64::{vaddq_f32, vdupq_n_f32, vld1q_f32, vmulq_f32, vst1q_f32};

    /// SAFETY: caller must ensure NEON (baseline on aarch64). Two
    /// 4-lane registers hold scalar lanes 0–3 and 4–7; separate mul +
    /// add (never `vmlaq_f32`/`vfmaq_f32`), fold in ascending lane
    /// order — bitwise the scalar reference.
    #[target_feature(enable = "neon")]
    pub unsafe fn dot_neon(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n8 = a.len() / LANES * LANES;
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        let mut i = 0;
        while i < n8 {
            let a0 = vld1q_f32(a.as_ptr().add(i));
            let b0 = vld1q_f32(b.as_ptr().add(i));
            let a1 = vld1q_f32(a.as_ptr().add(i + 4));
            let b1 = vld1q_f32(b.as_ptr().add(i + 4));
            acc0 = vaddq_f32(acc0, vmulq_f32(a0, b0));
            acc1 = vaddq_f32(acc1, vmulq_f32(a1, b1));
            i += LANES;
        }
        let mut lanes = [0.0f32; LANES];
        vst1q_f32(lanes.as_mut_ptr(), acc0);
        vst1q_f32(lanes.as_mut_ptr().add(4), acc1);
        let mut s = 0.0f32;
        for l in 0..LANES {
            s += lanes[l];
        }
        for j in n8..a.len() {
            s += a[j] * b[j];
        }
        s
    }

    /// SAFETY: caller must ensure NEON. Term order `(w * x) * x`.
    #[target_feature(enable = "neon")]
    pub unsafe fn weighted_sq_neon(w: &[f32], x: &[f32]) -> f32 {
        debug_assert_eq!(w.len(), x.len());
        let n8 = w.len() / LANES * LANES;
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        let mut i = 0;
        while i < n8 {
            let w0 = vld1q_f32(w.as_ptr().add(i));
            let x0 = vld1q_f32(x.as_ptr().add(i));
            let w1 = vld1q_f32(w.as_ptr().add(i + 4));
            let x1 = vld1q_f32(x.as_ptr().add(i + 4));
            acc0 = vaddq_f32(acc0, vmulq_f32(vmulq_f32(w0, x0), x0));
            acc1 = vaddq_f32(acc1, vmulq_f32(vmulq_f32(w1, x1), x1));
            i += LANES;
        }
        let mut lanes = [0.0f32; LANES];
        vst1q_f32(lanes.as_mut_ptr(), acc0);
        vst1q_f32(lanes.as_mut_ptr().add(4), acc1);
        let mut s = 0.0f32;
        for l in 0..LANES {
            s += lanes[l];
        }
        for j in n8..w.len() {
            s += w[j] * x[j] * x[j];
        }
        s
    }
}

/// Every tier that runs on this CPU (always includes `Scalar`) — the
/// iteration set for parity tests and bench rows.
pub fn supported_tiers() -> Vec<SimdTier> {
    [SimdTier::Scalar, SimdTier::Avx2, SimdTier::Neon]
        .into_iter()
        .filter(|t| t.supported())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::sync::Mutex;

    /// Serializes tests that touch the process-wide tier knob.
    static TIER_TEST_LOCK: Mutex<()> = Mutex::new(());

    fn serial_dot(a: &[f32], b: &[f32]) -> f64 {
        a.iter().zip(b).map(|(x, y)| (*x as f64) * (*y as f64)).sum()
    }

    #[test]
    fn dot_matches_serial_within_f32_tolerance() {
        let mut rng = Rng::new(3);
        for n in [0usize, 1, 7, 8, 9, 63, 64, 65, 1000] {
            let mut a = vec![0.0f32; n];
            let mut b = vec![0.0f32; n];
            rng.fill_normal(&mut a);
            rng.fill_normal(&mut b);
            let got = dot_lanes(&a, &b) as f64;
            let want = serial_dot(&a, &b);
            assert!(
                (got - want).abs() < 1e-4 * (1.0 + want.abs()),
                "n={n}: got {got} want {want}"
            );
        }
    }

    #[test]
    fn dot_is_deterministic_and_exact_on_integers() {
        // integer-valued f32s sum exactly, so any two orders agree
        let a: Vec<f32> = (0..37).map(|i| (i % 5) as f32).collect();
        let b: Vec<f32> = (0..37).map(|i| (i % 3) as f32).collect();
        assert_eq!(dot_lanes(&a, &b) as f64, serial_dot(&a, &b));
        assert_eq!(dot_lanes(&a, &b).to_bits(), dot_lanes(&a, &b).to_bits());
    }

    #[test]
    fn weighted_sq_matches_serial() {
        let mut rng = Rng::new(9);
        for n in [0usize, 5, 8, 100, 257] {
            let mut w = vec![0.0f32; n];
            let mut x = vec![0.0f32; n];
            rng.fill_normal(&mut w);
            rng.fill_normal(&mut x);
            let got = weighted_sq_lanes(&w, &x) as f64;
            let want: f64 = w
                .iter()
                .zip(&x)
                .map(|(wv, xv)| (*wv as f64) * (*xv as f64) * (*xv as f64))
                .sum();
            assert!(
                (got - want).abs() < 1e-3 * (1.0 + want.abs()),
                "n={n}: got {got} want {want}"
            );
        }
    }

    /// The cross-tier contract: every supported vector tier is bitwise
    /// the scalar reference, across lengths hitting every remainder
    /// lane (and the empty edge).
    #[test]
    fn vector_tiers_match_scalar_bitwise() {
        let mut rng = Rng::new(11);
        for n in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 31, 63, 64, 65, 257, 1000] {
            let mut a = vec![0.0f32; n];
            let mut b = vec![0.0f32; n];
            rng.fill_normal(&mut a);
            rng.fill_normal(&mut b);
            let dot0 = dot_lanes_tier(SimdTier::Scalar, &a, &b);
            let wsq0 = weighted_sq_lanes_tier(SimdTier::Scalar, &a, &b);
            for tier in supported_tiers() {
                let dot = dot_lanes_tier(tier, &a, &b);
                let wsq = weighted_sq_lanes_tier(tier, &a, &b);
                assert_eq!(dot.to_bits(), dot0.to_bits(), "dot {tier:?} n={n}");
                assert_eq!(wsq.to_bits(), wsq0.to_bits(), "weighted_sq {tier:?} n={n}");
            }
        }
    }

    #[test]
    fn parse_names_roundtrip_and_reject_garbage() {
        assert_eq!(SimdTier::parse("auto").unwrap(), None);
        for t in [SimdTier::Scalar, SimdTier::Avx2, SimdTier::Neon] {
            assert_eq!(SimdTier::parse(t.name()).unwrap(), Some(t));
        }
        assert!(SimdTier::parse("sse9").is_err());
        assert!(SimdTier::parse("").is_err());
    }

    #[test]
    fn explicit_tier_beats_auto_and_clears_back() {
        let _guard = TIER_TEST_LOCK.lock().unwrap();
        assert!(detect_tier().supported());
        set_global_simd(None);
        let auto = active_tier();
        assert!(auto.supported());
        set_global_simd(Some(SimdTier::Scalar));
        assert_eq!(active_tier(), SimdTier::Scalar);
        set_global_simd(None);
        assert_eq!(active_tier(), auto, "clearing must restore auto resolution");
    }

    /// A kernel defined via the dispatch macro runs the same body at
    /// every supported tier, bitwise.
    #[test]
    fn simd_kernel_macro_dispatches_bitwise() {
        #[inline(always)]
        fn scaled_sum_body(v: &[f32], k: f32, out: &mut [f32]) -> f32 {
            let mut s = 0.0f32;
            for (o, x) in out.iter_mut().zip(v) {
                *o = x * k;
                s += *o;
            }
            s
        }
        crate::simd_kernel!(fn scaled_sum(tier, v: &[f32], k: f32, out: &mut [f32]) -> f32 = scaled_sum_body);

        let mut rng = Rng::new(5);
        for n in [0usize, 1, 9, 64, 130] {
            let mut v = vec![0.0f32; n];
            rng.fill_normal(&mut v);
            let mut out0 = vec![0.0f32; n];
            let s0 = scaled_sum(SimdTier::Scalar, &v, 1.25, &mut out0);
            for tier in supported_tiers() {
                let mut out = vec![0.0f32; n];
                let s = scaled_sum(tier, &v, 1.25, &mut out);
                assert_eq!(s.to_bits(), s0.to_bits(), "{tier:?} n={n}");
                assert_eq!(out, out0, "{tier:?} n={n}");
            }
        }
    }
}
