//! Lane-unrolled reduction helpers for the hot kernels (no intrinsics,
//! no deps — plain loops shaped so the autovectorizer keeps the
//! accumulators in SIMD registers).
//!
//! Determinism (DESIGN.md §3): [`LANES`] is a fixed constant, so the
//! summation order of every helper — lane-strided partials folded in
//! lane order, scalar tail appended last — is a pure function of the
//! input length. Nothing here depends on the thread count; results are
//! bit-identical wherever the call runs.

/// Independent accumulator lanes in the reduction helpers. Wide enough
/// to fill one AVX register (or two SSE registers) of `f32`s and to
/// break the serial FP dependency chain; never derived from the
/// machine, so the reduction order is portable.
pub const LANES: usize = 8;

/// `sum_i a[i] * b[i]` with [`LANES`] independent accumulators: lane
/// `l` sums elements `l, l + LANES, ...`; lanes fold in ascending lane
/// order and the `len % LANES` tail is added last.
#[inline]
pub fn dot_lanes(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; LANES];
    let mut ach = a.chunks_exact(LANES);
    let mut bch = b.chunks_exact(LANES);
    for (av, bv) in (&mut ach).zip(&mut bch) {
        let av: &[f32; LANES] = av.try_into().unwrap();
        let bv: &[f32; LANES] = bv.try_into().unwrap();
        for l in 0..LANES {
            acc[l] += av[l] * bv[l];
        }
    }
    let mut s = 0.0f32;
    for l in 0..LANES {
        s += acc[l];
    }
    for (av, bv) in ach.remainder().iter().zip(bch.remainder()) {
        s += av * bv;
    }
    s
}

/// `sum_i w[i] * x[i] * x[i]` (a diagonally-weighted squared norm —
/// the linear2 exact-Fisher reduction), with the same fixed lane
/// order as [`dot_lanes`].
#[inline]
pub fn weighted_sq_lanes(w: &[f32], x: &[f32]) -> f32 {
    debug_assert_eq!(w.len(), x.len());
    let mut acc = [0.0f32; LANES];
    let mut wch = w.chunks_exact(LANES);
    let mut xch = x.chunks_exact(LANES);
    for (wv, xv) in (&mut wch).zip(&mut xch) {
        let wv: &[f32; LANES] = wv.try_into().unwrap();
        let xv: &[f32; LANES] = xv.try_into().unwrap();
        for l in 0..LANES {
            acc[l] += wv[l] * xv[l] * xv[l];
        }
    }
    let mut s = 0.0f32;
    for l in 0..LANES {
        s += acc[l];
    }
    for (wv, xv) in wch.remainder().iter().zip(xch.remainder()) {
        s += wv * xv * xv;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn serial_dot(a: &[f32], b: &[f32]) -> f64 {
        a.iter().zip(b).map(|(x, y)| (*x as f64) * (*y as f64)).sum()
    }

    #[test]
    fn dot_matches_serial_within_f32_tolerance() {
        let mut rng = Rng::new(3);
        for n in [0usize, 1, 7, 8, 9, 63, 64, 65, 1000] {
            let mut a = vec![0.0f32; n];
            let mut b = vec![0.0f32; n];
            rng.fill_normal(&mut a);
            rng.fill_normal(&mut b);
            let got = dot_lanes(&a, &b) as f64;
            let want = serial_dot(&a, &b);
            assert!(
                (got - want).abs() < 1e-4 * (1.0 + want.abs()),
                "n={n}: got {got} want {want}"
            );
        }
    }

    #[test]
    fn dot_is_deterministic_and_exact_on_integers() {
        // integer-valued f32s sum exactly, so any two orders agree
        let a: Vec<f32> = (0..37).map(|i| (i % 5) as f32).collect();
        let b: Vec<f32> = (0..37).map(|i| (i % 3) as f32).collect();
        assert_eq!(dot_lanes(&a, &b) as f64, serial_dot(&a, &b));
        assert_eq!(dot_lanes(&a, &b).to_bits(), dot_lanes(&a, &b).to_bits());
    }

    #[test]
    fn weighted_sq_matches_serial() {
        let mut rng = Rng::new(9);
        for n in [0usize, 5, 8, 100, 257] {
            let mut w = vec![0.0f32; n];
            let mut x = vec![0.0f32; n];
            rng.fill_normal(&mut w);
            rng.fill_normal(&mut x);
            let got = weighted_sq_lanes(&w, &x) as f64;
            let want: f64 = w
                .iter()
                .zip(&x)
                .map(|(wv, xv)| (*wv as f64) * (*xv as f64) * (*xv as f64))
                .sum();
            assert!(
                (got - want).abs() < 1e-3 * (1.0 + want.abs()),
                "n={n}: got {got} want {want}"
            );
        }
    }
}
