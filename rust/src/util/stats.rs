//! Summary statistics for metrics and the bench harness.

/// Streaming summary: count/mean/min/max + reservoir of values for
/// percentile queries (benchmark sample counts are small, so we just
/// keep everything).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    values: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, v: f64) {
        self.values.push(v);
    }

    pub fn count(&self) -> usize {
        self.values.len()
    }

    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        self.values.iter().sum::<f64>() / self.values.len() as f64
    }

    pub fn std(&self) -> f64 {
        let n = self.values.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.values.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / (n - 1) as f64).sqrt()
    }

    pub fn min(&self) -> f64 {
        self.values.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.values.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Linear-interpolated percentile, `q` in `[0, 100]`.
    pub fn percentile(&self, q: f64) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        let mut v = self.values.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pos = (q / 100.0) * (v.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            v[lo]
        } else {
            v[lo] + (v[hi] - v[lo]) * (pos - lo as f64)
        }
    }

    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }
}

/// Ordinary least squares slope of y over x — used by experiment
/// regenerators to characterize loss-curve trends.
pub fn ols_slope(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let cov: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let var: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    cov / var
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        for v in [1.0, 2.0, 3.0, 4.0] {
            s.add(v);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert!((s.median() - 2.5).abs() < 1e-12);
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-12);
        assert!((s.percentile(100.0) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn std_of_constant_is_zero() {
        let mut s = Summary::new();
        for _ in 0..5 {
            s.add(3.0);
        }
        assert_eq!(s.std(), 0.0);
    }

    #[test]
    fn slope_of_line() {
        let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 2.0 * x + 1.0).collect();
        assert!((ols_slope(&xs, &ys) - 2.0).abs() < 1e-12);
    }
}
