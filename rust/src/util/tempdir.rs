//! Self-deleting temp directories (the tempdir crate is not vendored).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static COUNTER: AtomicU64 = AtomicU64::new(0);

pub struct TempDir(PathBuf);

impl TempDir {
    pub fn new() -> TempDir {
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        let p = std::env::temp_dir().join(format!("lotion_{}_{}", std::process::id(), n));
        std::fs::create_dir_all(&p).unwrap();
        TempDir(p)
    }

    pub fn path(&self) -> &Path {
        &self.0
    }
}

impl Default for TempDir {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}
