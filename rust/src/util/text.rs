//! Tiny text helpers: edit distance + nearest-candidate suggestion for
//! "unknown key — did you mean ...?" diagnostics (sweep-spec keys,
//! strict config-TOML keys).

/// Levenshtein edit distance (insert/delete/substitute, all cost 1).
pub fn edit_distance(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    if a.is_empty() {
        return b.len();
    }
    if b.is_empty() {
        return a.len();
    }
    // one rolling row
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// The candidate closest to `target` by edit distance, if any is close
/// enough to plausibly be a typo (distance ≤ 2, and strictly less than
/// the target's own length so 2-char keys don't match everything).
pub fn nearest<'a>(target: &str, candidates: impl IntoIterator<Item = &'a str>) -> Option<&'a str> {
    candidates
        .into_iter()
        .map(|c| (edit_distance(target, c), c))
        .min_by_key(|&(d, c)| (d, c.len()))
        .filter(|&(d, _)| d <= 2 && d < target.chars().count())
        .map(|(_, c)| c)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distance_basics() {
        assert_eq!(edit_distance("", ""), 0);
        assert_eq!(edit_distance("abc", "abc"), 0);
        assert_eq!(edit_distance("abc", ""), 3);
        assert_eq!(edit_distance("kitten", "sitting"), 3);
        assert_eq!(edit_distance("stpes", "steps"), 2);
        assert_eq!(edit_distance("lamda", "lambda"), 1);
    }

    #[test]
    fn nearest_suggests_plausible_typos_only() {
        let keys = ["steps", "lr", "lambda", "schedule"];
        assert_eq!(nearest("stpes", keys), Some("steps"));
        assert_eq!(nearest("lamda", keys), Some("lambda"));
        assert_eq!(nearest("zzzzzz", keys), None);
        // exact match still reports itself (callers check membership first)
        assert_eq!(nearest("lr", keys), Some("lr"));
        // a 2-char unknown must not fuzzy-match a 2-char key
        assert_eq!(nearest("qq", keys), None);
    }
}
