//! ISSUE 7 acceptance: crash safety rides on the determinism contract.
//! A run killed at step N and resumed from its last checkpoint must be
//! **bit-identical** to the uninterrupted run — final params, train
//! losses, eval curves, even the JSONL metrics file — at any
//! `--threads` width. Likewise an interrupted sweep resumed from its
//! journal folds bitwise-equal results, and a panicking grid point
//! retried on a fresh engine is transparent to the sweep's output.
//!
//! Faults are injected deterministically via `util::faults`: in-process
//! tests install thread-local `ScopedPlan`s; the subprocess tests drive
//! the real CLI with `LOTION_FAULTS=kill@...` and assert on exit code
//! [`KILL_EXIT`] plus the bytes left on disk.

use anyhow::Result;
use lotion::checkpoint::Checkpoint;
use lotion::config::{RunConfig, Schedule};
use lotion::coordinator::sweep::lr_points;
use lotion::coordinator::{
    CkptPolicy, DataSource, Evaluator, JournalEntry, MetricsLogger, SweepJournal, SweepResult,
    SweepRunner, Trainer,
};
use lotion::data::{ByteTokenizer, TokenBatcher, ZipfMarkovCorpus};
use lotion::experiments::common::synth_statics;
use lotion::runtime::native::{
    LmConfig, LmProgram, ModelSpec, NativeEngine, NativeFactory, NativeModel, OptKind,
};
use lotion::runtime::Executor;
use lotion::tensor::HostTensor;
use lotion::util::faults::{ScopedPlan, KILL_EXIT};
use lotion::util::tempdir::TempDir;
use std::collections::{BTreeMap, HashSet};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::process::Command;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

fn bits(t: &HostTensor) -> Vec<u32> {
    t.as_f32().iter().map(|v| v.to_bits()).collect()
}

/// Bit-exact train-loss trace of a run (or run fragment).
fn trains(m: &MetricsLogger) -> Vec<String> {
    m.train_losses.iter().map(|(s, l)| format!("t{s}:{:016x}", l.to_bits())).collect()
}

/// Bit-exact eval curve of a run (or run fragment).
fn evals(m: &MetricsLogger) -> Vec<String> {
    m.eval_points
        .iter()
        .map(|p| format!("e{}:{}:{}:{:016x}", p.step, p.format, p.rounding, p.val_loss.to_bits()))
        .collect()
}

fn concat(a: Vec<String>, b: Vec<String>) -> Vec<String> {
    let mut v = a;
    v.extend(b);
    v
}

// ---------------------------------------------------------------------------
// linreg: kill at a step boundary, resume, compare everything bitwise
// ---------------------------------------------------------------------------

fn linreg_engine(threads: usize) -> NativeEngine {
    NativeEngine::with_models(&[NativeModel::from_spec(
        ModelSpec::LinReg { d: 256, batch: 64 },
        OptKind::Sgd,
        8,
    )])
    .with_threads(threads)
}

fn linreg_cfg() -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.name = "crash_linreg".into();
    cfg.method = "lotion".into();
    cfg.format = "int4".into();
    cfg.eval_formats = vec!["int4".into()];
    cfg.steps = 24;
    cfg.lr = 0.05;
    cfg.lambda = 1.0;
    cfg.eval_every = 8;
    cfg.schedule = Schedule::Constant;
    cfg.seed = 5;
    cfg
}

fn linreg_inputs(
    _: &dyn Executor,
    _: &RunConfig,
) -> Result<(Vec<(String, HostTensor)>, DataSource)> {
    let (statics, _, _) = synth_statics(256, 3);
    Ok((statics, DataSource::InGraph))
}

/// One uninterrupted run with periodic checkpoints into `dir`.
fn linreg_uninterrupted(threads: usize, dir: &Path) -> (Vec<u32>, MetricsLogger) {
    let engine = linreg_engine(threads);
    let (statics, _, _) = synth_statics(256, 3);
    let mut trainer = Trainer::new(&engine, linreg_cfg(), statics, DataSource::InGraph).unwrap();
    let mut eval = Evaluator::new(5);
    let mut metrics = MetricsLogger::in_memory();
    let policy = CkptPolicy { dir: dir.to_path_buf(), every: 8 };
    trainer.run_with_checkpoints(&mut eval, &mut metrics, Some(&policy), None).unwrap();
    (bits(&trainer.state().fetch("w").unwrap()), metrics)
}

/// The same run interrupted by `panic@step:16`, then resumed on a
/// *fresh* engine + trainer from the snapshot the interrupted run left.
fn linreg_interrupted_resumed(
    threads: usize,
    dir: &Path,
) -> (Vec<u32>, MetricsLogger, MetricsLogger) {
    let policy = CkptPolicy { dir: dir.to_path_buf(), every: 8 };
    let mut metrics_b = MetricsLogger::in_memory();
    {
        let engine = linreg_engine(threads);
        let (statics, _, _) = synth_statics(256, 3);
        let mut trainer =
            Trainer::new(&engine, linreg_cfg(), statics, DataSource::InGraph).unwrap();
        let mut eval = Evaluator::new(5);
        let _g = ScopedPlan::install("panic@step:16").unwrap();
        let r = catch_unwind(AssertUnwindSafe(|| {
            trainer.run_with_checkpoints(&mut eval, &mut metrics_b, Some(&policy), None)
        }));
        assert!(r.is_err(), "injected panic@step:16 did not fire");
    }
    let engine = linreg_engine(threads);
    let (statics, _, _) = synth_statics(256, 3);
    let mut trainer = Trainer::new(&engine, linreg_cfg(), statics, DataSource::InGraph).unwrap();
    let mut eval = Evaluator::new(5);
    let ckpt = Checkpoint::load(&dir.join("step000016.lotn")).unwrap();
    let next_eval = trainer.restore(&mut eval, &ckpt).unwrap();
    assert_eq!(trainer.step, 16, "restore must reposition the step counter");
    assert_eq!(next_eval, 16, "eval cadence must resume where it left off");
    let mut metrics_c = MetricsLogger::in_memory();
    trainer
        .run_with_checkpoints(&mut eval, &mut metrics_c, Some(&policy), Some(next_eval))
        .unwrap();
    (bits(&trainer.state().fetch("w").unwrap()), metrics_b, metrics_c)
}

/// ISSUE 7 acceptance criterion (linreg): interrupted + resumed ==
/// uninterrupted, bit for bit, at `--threads 1` and auto. The
/// periodic snapshots the two runs write are themselves byte-identical
/// files — including the one the interrupted run wrote on its way down.
#[test]
fn linreg_kill_resume_is_bit_identical() {
    for threads in [1usize, 0] {
        let da = TempDir::new();
        let db = TempDir::new();
        let (wa, ma) = linreg_uninterrupted(threads, da.path());
        let (wb, mb, mc) = linreg_interrupted_resumed(threads, db.path());
        assert_eq!(wa, wb, "threads={threads}: final params differ after resume");
        assert_eq!(
            trains(&ma),
            concat(trains(&mb), trains(&mc)),
            "threads={threads}: train-loss trace differs"
        );
        assert_eq!(
            evals(&ma),
            concat(evals(&mb), evals(&mc)),
            "threads={threads}: eval curve differs"
        );
        for name in ["step000008.lotn", "step000016.lotn", "step000024.lotn"] {
            let a = std::fs::read(da.path().join(name)).unwrap();
            let b = std::fs::read(db.path().join(name)).unwrap();
            assert_eq!(a, b, "threads={threads}: snapshot {name} differs byte-wise");
        }
    }
}

// ---------------------------------------------------------------------------
// transformer LM: resume restores both RNG streams, the token pipeline
// position and the pinned validation chunk
// ---------------------------------------------------------------------------

fn lm_engine(threads: usize) -> NativeEngine {
    let program = LmProgram::new(
        "lm-crash-test",
        LmConfig { vocab: 256, d_model: 16, n_layers: 1, n_heads: 2, seq_len: 16 },
        2,
        1,
    )
    .unwrap();
    NativeEngine::with_models(&[NativeModel {
        program: Arc::new(program),
        opt: OptKind::Adam,
        steps_per_call: 4,
    }])
    .with_threads(threads)
}

fn lm_cfg() -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.name = "crash_lm".into();
    cfg.model = "lm-crash-test".into();
    cfg.method = "lotion".into();
    cfg.format = "int8".into();
    cfg.eval_formats = vec!["int8".into()];
    cfg.steps = 12;
    cfg.lr = 3e-3;
    cfg.lambda = 10.0;
    cfg.eval_every = 4;
    cfg.schedule = Schedule::Constant;
    cfg.seed = 23;
    cfg
}

fn lm_batcher() -> TokenBatcher {
    let corpus = ZipfMarkovCorpus::generate(20_000, 256, 4, 9);
    TokenBatcher::new(ByteTokenizer::new().encode(&corpus.bytes), 2, 16, 0.1)
}

/// The LM path exercises everything the linreg one cannot: Adam
/// moments in the snapshot, a host-side token stream driven by the
/// trainer RNG, RR eval casts driven by the eval RNG mid-stream, and
/// the pinned validation chunk riding in the checkpoint.
#[test]
fn lm_kill_resume_is_bit_identical() {
    for threads in [1usize, 0] {
        let da = TempDir::new();
        let db = TempDir::new();
        let policy_a = CkptPolicy { dir: da.path().to_path_buf(), every: 4 };
        let policy_b = CkptPolicy { dir: db.path().to_path_buf(), every: 4 };

        let engine = lm_engine(threads);
        let mut trainer =
            Trainer::new(&engine, lm_cfg(), vec![], DataSource::Tokens(lm_batcher())).unwrap();
        let mut eval = Evaluator::new(23);
        let mut ma = MetricsLogger::in_memory();
        trainer.run_with_checkpoints(&mut eval, &mut ma, Some(&policy_a), None).unwrap();
        let wa = bits(&trainer.state().fetch("embed").unwrap());
        drop(trainer);

        let mut mb = MetricsLogger::in_memory();
        {
            let engine = lm_engine(threads);
            let mut trainer =
                Trainer::new(&engine, lm_cfg(), vec![], DataSource::Tokens(lm_batcher())).unwrap();
            let mut eval = Evaluator::new(23);
            let _g = ScopedPlan::install("panic@step:8").unwrap();
            let r = catch_unwind(AssertUnwindSafe(|| {
                trainer.run_with_checkpoints(&mut eval, &mut mb, Some(&policy_b), None)
            }));
            assert!(r.is_err(), "injected panic@step:8 did not fire");
        }
        let engine = lm_engine(threads);
        let mut trainer =
            Trainer::new(&engine, lm_cfg(), vec![], DataSource::Tokens(lm_batcher())).unwrap();
        let mut eval = Evaluator::new(23);
        let ckpt = Checkpoint::load(&db.path().join("step000008.lotn")).unwrap();
        assert!(
            ckpt.get(lotion::coordinator::trainer::VAL_TOKENS_KEY).is_some(),
            "LM snapshot must carry the pinned validation chunk"
        );
        let next_eval = trainer.restore(&mut eval, &ckpt).unwrap();
        let mut mc = MetricsLogger::in_memory();
        trainer
            .run_with_checkpoints(&mut eval, &mut mc, Some(&policy_b), Some(next_eval))
            .unwrap();
        let wb = bits(&trainer.state().fetch("embed").unwrap());

        assert_eq!(wa, wb, "threads={threads}: LM embed differs after resume");
        assert_eq!(trains(&ma), concat(trains(&mb), trains(&mc)), "threads={threads}");
        assert_eq!(evals(&ma), concat(evals(&mb), evals(&mc)), "threads={threads}");
    }
}

/// Resuming into a *different* result-determining configuration must
/// refuse (the digest guard), not silently continue the wrong run.
#[test]
fn resume_refuses_a_mismatched_config() {
    let dir = TempDir::new();
    let engine = linreg_engine(1);
    let (statics, _, _) = synth_statics(256, 3);
    let trainer = Trainer::new(&engine, linreg_cfg(), statics, DataSource::InGraph).unwrap();
    let eval = Evaluator::new(5);
    let path = dir.path().join("snap.lotn");
    trainer.save_checkpoint(&eval, 0, &path).unwrap();

    let mut other = linreg_cfg();
    other.lr = 0.07; // result-determining: digest changes
    let engine2 = linreg_engine(1);
    let (statics, _, _) = synth_statics(256, 3);
    let mut trainer2 = Trainer::new(&engine2, other, statics, DataSource::InGraph).unwrap();
    let mut eval2 = Evaluator::new(5);
    let ckpt = Checkpoint::load(&path).unwrap();
    let err = trainer2.restore(&mut eval2, &ckpt).unwrap_err();
    assert!(err.to_string().contains("digest"), "unexpected error: {err}");
}

// ---------------------------------------------------------------------------
// sweep journal: interrupted grids resume bitwise, stale digests re-run
// ---------------------------------------------------------------------------

fn sweep_factory() -> NativeFactory {
    NativeFactory::new(
        vec![NativeModel::from_spec(ModelSpec::LinReg { d: 256, batch: 64 }, OptKind::Sgd, 8)],
        0,
    )
}

fn sweep_cfg() -> RunConfig {
    let mut cfg = linreg_cfg();
    cfg.name = "crash_sweep".into();
    cfg.steps = 16;
    cfg.eval_every = 16;
    cfg
}

/// (label, score bits, diverged) per point — what resume must reproduce.
fn fingerprint(results: &[SweepResult]) -> Vec<String> {
    results
        .iter()
        .map(|r| format!("{} {:016x} {}", r.label, r.score.to_bits(), r.diverged))
        .collect()
}

/// An 8-point grid journaled to completion, then "resumed" from a
/// journal holding only the first 5 entries — one of them with a
/// corrupted digest. The resumed sweep must execute exactly the 3
/// missing points plus the stale-digest one, and fold results
/// bitwise-equal to the full run, serial and sharded.
#[test]
fn interrupted_sweep_resume_is_bitwise_equal() {
    let factory = sweep_factory();
    let base = sweep_cfg();
    let lrs: Vec<f64> = (1..=8).map(|i| 0.01 * i as f64).collect();
    let dir = TempDir::new();

    let r1 = SweepRunner::new(&factory, 1)
        .with_journal(&dir.path().join("full.jsonl"), Vec::new())
        .unwrap()
        .run(lr_points(&base, &lrs), "int4", "rtn", &linreg_inputs)
        .unwrap();
    let fp1 = fingerprint(&r1);
    assert!(r1.iter().all(|r| !r.diverged));
    let full = SweepJournal::completed(&dir.path().join("full.jsonl")).unwrap();
    assert_eq!(full.len(), 8);

    let mut resume: Vec<JournalEntry> = full[..5].to_vec();
    resume[4].digest = "0000000000000000".into(); // stale: must re-run
    let labels: Vec<String> =
        lr_points(&base, &lrs).into_iter().map(|p| p.label).collect();

    let executed = Mutex::new(HashSet::new());
    let inputs = |e: &dyn Executor, cfg: &RunConfig| {
        executed.lock().unwrap().insert(cfg.name.clone());
        linreg_inputs(e, cfg)
    };
    let r2 = SweepRunner::new(&factory, 1)
        .with_journal(&dir.path().join("resumed.jsonl"), resume.clone())
        .unwrap()
        .run(lr_points(&base, &lrs), "int4", "rtn", &inputs)
        .unwrap();
    assert_eq!(fingerprint(&r2), fp1, "serial resume must fold bitwise-equal results");
    {
        let ex = executed.lock().unwrap();
        // env fault plans may retry a point transparently, so count
        // *distinct labels executed*, not input-builder invocations
        assert_eq!(ex.len(), 4, "executed: {ex:?}");
        for i in [4usize, 5, 6, 7] {
            assert!(ex.contains(&labels[i]), "point {i} ({}) should have re-run", labels[i]);
        }
    }

    let r3 = SweepRunner::new(&factory, 3)
        .with_journal(&dir.path().join("resumed_sharded.jsonl"), resume)
        .unwrap()
        .run(lr_points(&base, &lrs), "int4", "rtn", &linreg_inputs)
        .unwrap();
    assert_eq!(fingerprint(&r3), fp1, "sharded resume must fold bitwise-equal results");

    // the resumed journal re-journals only what it ran: 4 new lines
    let resumed = SweepJournal::completed(&dir.path().join("resumed.jsonl")).unwrap();
    assert_eq!(resumed.len(), 4);
}

/// A grid point whose first attempt panics is retried on a freshly
/// spawned engine; determinism makes the retry transparent — the sweep
/// output equals a clean run bit for bit, serial and sharded — and the
/// journal records the extra attempt.
#[test]
fn panicking_point_is_retried_on_a_fresh_engine() {
    let factory = sweep_factory();
    let mut base = sweep_cfg();
    base.name = "crash_retry".into();
    let lrs = [0.01, 0.02, 0.03];
    let clean = SweepRunner::new(&factory, 1)
        .run(lr_points(&base, &lrs), "int4", "rtn", &linreg_inputs)
        .unwrap();
    let fp = fingerprint(&clean);
    let dir = TempDir::new();

    for workers in [1usize, 3] {
        let tripped = AtomicBool::new(false);
        let inputs = |e: &dyn Executor, cfg: &RunConfig| {
            if cfg.lr == 0.02 && !tripped.swap(true, Ordering::SeqCst) {
                panic!("transient input failure");
            }
            linreg_inputs(e, cfg)
        };
        let jp = dir.path().join(format!("retry_w{workers}.jsonl"));
        let r = SweepRunner::new(&factory, workers)
            .with_journal(&jp, Vec::new())
            .unwrap()
            .run(lr_points(&base, &lrs), "int4", "rtn", &inputs)
            .unwrap();
        assert_eq!(fingerprint(&r), fp, "workers={workers}: retry must be transparent");
        let entries = SweepJournal::completed(&jp).unwrap();
        let mid = entries.iter().find(|e| e.lr == 0.02).expect("journaled");
        assert_eq!(mid.status, "ok");
        assert_eq!(mid.attempts, 2, "workers={workers}: the retry must be recorded");
    }
}

/// Exhausted retries fold the point as `failed` / +inf without killing
/// the sweep or perturbing its siblings.
#[test]
fn exhausted_retries_fold_as_failed() {
    let factory = sweep_factory();
    let mut base = sweep_cfg();
    base.name = "crash_exhaust".into();
    let lrs = [0.01, 0.02, 0.03];
    let clean = SweepRunner::new(&factory, 1)
        .run(lr_points(&base, &lrs), "int4", "rtn", &linreg_inputs)
        .unwrap();
    let inputs = |e: &dyn Executor, cfg: &RunConfig| {
        if cfg.lr == 0.02 {
            panic!("persistent input failure");
        }
        linreg_inputs(e, cfg)
    };
    let dir = TempDir::new();
    let jp = dir.path().join("exhaust.jsonl");
    let r = SweepRunner::new(&factory, 1)
        .with_retries(2)
        .with_journal(&jp, Vec::new())
        .unwrap()
        .run(lr_points(&base, &lrs), "int4", "rtn", &inputs)
        .unwrap();
    assert!(r[1].diverged && r[1].score.is_infinite());
    assert_eq!(r[0].score.to_bits(), clean[0].score.to_bits(), "sibling 0 perturbed");
    assert_eq!(r[2].score.to_bits(), clean[2].score.to_bits(), "sibling 2 perturbed");
    let entries = SweepJournal::completed(&jp).unwrap();
    let mid = entries.iter().find(|e| e.lr == 0.02).expect("journaled");
    assert_eq!(mid.status, "failed");
    assert_eq!(mid.attempts, 3, "retries=2 means 3 attempts");
    assert_eq!(mid.score.to_bits(), f64::INFINITY.to_bits());
    assert!(
        mid.error.as_deref().unwrap_or("").contains("persistent input failure"),
        "journal must carry the panic message: {:?}",
        mid.error
    );
}

/// Deterministic divergence is a *data point*: recorded structured,
/// journaled as `diverged` with the step/loss/lr that blew up, and
/// never retried (it would diverge identically again).
#[test]
fn divergence_is_recorded_and_never_retried() {
    // direct trainer path: the structured record lands before the bail
    let engine = linreg_engine(1);
    let (statics, _, _) = synth_statics(256, 3);
    let mut cfg = linreg_cfg();
    cfg.lr = 1e8;
    let mut trainer = Trainer::new(&engine, cfg, statics, DataSource::InGraph).unwrap();
    let mut eval = Evaluator::new(5);
    let mut metrics = MetricsLogger::in_memory();
    assert!(trainer.run(&mut eval, &mut metrics).is_err());
    let rec = metrics.diverged.as_ref().expect("divergence must be recorded");
    assert!(!rec.loss.is_finite());
    assert!(rec.step > 0);
    assert_eq!(rec.method, "lotion");

    // sweep path: journaled as status=diverged, attempts=1 despite a
    // generous retry budget
    let factory = sweep_factory();
    let mut base = sweep_cfg();
    base.name = "crash_diverge".into();
    let calls = Mutex::new(0usize);
    let inputs = |e: &dyn Executor, cfg: &RunConfig| {
        *calls.lock().unwrap() += 1;
        linreg_inputs(e, cfg)
    };
    let dir = TempDir::new();
    let jp = dir.path().join("diverge.jsonl");
    let r = SweepRunner::new(&factory, 1)
        .with_retries(3)
        .with_journal(&jp, Vec::new())
        .unwrap()
        .run(lr_points(&base, &[1e8]), "int4", "rtn", &inputs)
        .unwrap();
    assert!(r[0].diverged && r[0].score.is_infinite());
    assert_eq!(*calls.lock().unwrap(), 1, "divergence must not be retried");
    let entries = SweepJournal::completed(&jp).unwrap();
    assert_eq!(entries[0].status, "diverged");
    assert_eq!(entries[0].attempts, 1);
    assert!(
        entries[0].error.as_deref().unwrap_or("").contains("diverged at step"),
        "journal must carry the divergence record: {:?}",
        entries[0].error
    );
}

// ---------------------------------------------------------------------------
// subprocess: the real CLI under LOTION_FAULTS kill plans
// ---------------------------------------------------------------------------

/// `--set` overrides pinning a deterministic 24-step linreg run
/// (default model linreg_d256, K=8 in the default registry).
const TRAIN_SETS: &[&str] = &[
    "--set", "train.steps=24",
    "--set", "eval.every=8",
    "--set", "train.schedule=constant",
    "--set", "train.lr=0.05",
    "--set", "train.lambda=1.0",
    "--set", "seed=5",
];

fn train_cmd(cwd: &Path, out: &str) -> Command {
    let mut c = Command::new(env!("CARGO_BIN_EXE_lotion-rs"));
    c.current_dir(cwd)
        .args(["train", "--backend", "native"])
        .args(TRAIN_SETS)
        .args(["--ckpt-every", "8", "--out", out])
        .env_remove("LOTION_FAULTS")
        .env_remove("LOTION_THREADS")
        .env_remove("LOTION_CKPT_EVERY")
        .env_remove("LOTION_CKPT_DIR")
        .env_remove("LOTION_SWEEP_WORKERS");
    c
}

/// The metrics JSONL with the (nondeterministic) wall-clock field
/// stripped — every other field is bit-determined.
fn metrics_lines(path: &Path) -> Vec<String> {
    std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("reading {path:?}: {e}"))
        .lines()
        .map(|l| l.split(",\"wall_s\"").next().unwrap().to_string())
        .collect()
}

fn assert_success(out: &std::process::Output, what: &str) {
    assert!(
        out.status.success(),
        "{what} failed ({:?}):\n{}",
        out.status,
        String::from_utf8_lossy(&out.stderr)
    );
}

/// End-to-end CLI contract: a run killed by `LOTION_FAULTS=kill@step:16`
/// exits with [`KILL_EXIT`], leaves a resumable snapshot, and
/// `--resume` completes it bit-identical to uninterrupted baselines —
/// across *different* `LOTION_THREADS` settings for every leg.
#[test]
fn cli_kill_at_step_and_resume_is_bit_identical() {
    let dir = TempDir::new();
    let a1 = train_cmd(dir.path(), "a1").env("LOTION_THREADS", "1").output().unwrap();
    assert_success(&a1, "baseline train (threads=1)");
    let a2 = train_cmd(dir.path(), "a2").output().unwrap();
    assert_success(&a2, "baseline train (threads=auto)");
    let final_a1 = std::fs::read(dir.path().join("a1/final.lotn")).unwrap();
    assert_eq!(
        final_a1,
        std::fs::read(dir.path().join("a2/final.lotn")).unwrap(),
        "final checkpoint differs across LOTION_THREADS"
    );
    let lines_a1 = metrics_lines(&dir.path().join("a1/metrics.jsonl"));
    assert_eq!(lines_a1, metrics_lines(&dir.path().join("a2/metrics.jsonl")));

    let killed = train_cmd(dir.path(), "b")
        .env("LOTION_FAULTS", "kill@step:16")
        .output()
        .unwrap();
    assert_eq!(
        killed.status.code(),
        Some(KILL_EXIT),
        "kill@step:16 should exit {KILL_EXIT}: {}",
        String::from_utf8_lossy(&killed.stderr)
    );
    assert!(dir.path().join("b/step000016.lotn").exists(), "snapshot missing after kill");
    assert!(!dir.path().join("b/final.lotn").exists(), "killed run must not finalize");

    // resume at a different thread width than the killed run
    let resumed = train_cmd(dir.path(), "b")
        .arg("--resume")
        .arg(dir.path().join("b"))
        .env("LOTION_THREADS", "1")
        .output()
        .unwrap();
    assert_success(&resumed, "resume");
    assert_eq!(
        final_a1,
        std::fs::read(dir.path().join("b/final.lotn")).unwrap(),
        "resumed final checkpoint differs from uninterrupted"
    );
    assert_eq!(
        lines_a1,
        metrics_lines(&dir.path().join("b/metrics.jsonl")),
        "appended metrics JSONL differs from uninterrupted"
    );
}

/// Atomicity proof at the CLI level: a kill *between the temp-file
/// fsync and the rename* (the `ckpt_save` site) must leave the target
/// checkpoint unpublished and the previous snapshot intact — resume
/// falls back one checkpoint and still converges bit-identically.
#[test]
fn cli_kill_during_checkpoint_save_preserves_previous_snapshot() {
    let dir = TempDir::new();
    let base = train_cmd(dir.path(), "a").output().unwrap();
    assert_success(&base, "baseline train");
    let final_a = std::fs::read(dir.path().join("a/final.lotn")).unwrap();

    // save sequence in a fresh process: step8 = 1, step16 = 2
    let killed = train_cmd(dir.path(), "b")
        .env("LOTION_FAULTS", "kill@ckpt_save:2")
        .output()
        .unwrap();
    assert_eq!(killed.status.code(), Some(KILL_EXIT));
    assert!(
        !dir.path().join("b/step000016.lotn").exists(),
        "a kill before the rename must not publish the snapshot"
    );
    Checkpoint::load(&dir.path().join("b/step000008.lotn"))
        .expect("previous snapshot must stay intact");

    let resumed = train_cmd(dir.path(), "b")
        .arg("--resume")
        .arg(dir.path().join("b"))
        .output()
        .unwrap();
    assert_success(&resumed, "resume from the previous snapshot");
    assert_eq!(
        final_a,
        std::fs::read(dir.path().join("b/final.lotn")).unwrap(),
        "resume from an older snapshot must still converge bit-identically"
    );
}

/// Sweep CLI: `kill@point:5` journals the 5 completed points and exits
/// [`KILL_EXIT`]; `--resume-sweep` finishes the remaining 3 and the
/// union journal carries the same bit-exact scores as a clean sweep.
#[test]
fn cli_sweep_kill_and_resume_completes_the_journal() {
    let dir = TempDir::new();
    let sets: &[&str] = &[
        "--set", "train.steps=16",
        "--set", "eval.every=16",
        "--set", "train.schedule=constant",
        "--set", "train.lambda=1.0",
        "--set", "seed=5",
    ];
    let lrs = "0.01,0.02,0.03,0.04,0.05,0.06,0.07,0.08";
    let sweep_cmd = |journal: &str| {
        let mut c = Command::new(env!("CARGO_BIN_EXE_lotion-rs"));
        c.current_dir(dir.path())
            .args(["sweep", "--backend", "native", "--lrs", lrs, "--journal", journal])
            .args(sets)
            .env_remove("LOTION_FAULTS")
            .env_remove("LOTION_THREADS")
            .env_remove("LOTION_SWEEP_WORKERS");
        c
    };
    let by_label = |path: &Path| -> BTreeMap<String, (u64, String)> {
        SweepJournal::completed(path)
            .unwrap()
            .into_iter()
            .map(|e| (e.label, (e.score.to_bits(), e.status)))
            .collect()
    };

    let clean = sweep_cmd("clean.jsonl").output().unwrap();
    assert_success(&clean, "clean sweep");
    let clean_map = by_label(&dir.path().join("clean.jsonl"));
    assert_eq!(clean_map.len(), 8);

    let killed = sweep_cmd("sweep.jsonl")
        .env("LOTION_FAULTS", "kill@point:5")
        .output()
        .unwrap();
    assert_eq!(
        killed.status.code(),
        Some(KILL_EXIT),
        "kill@point:5 should exit {KILL_EXIT}: {}",
        String::from_utf8_lossy(&killed.stderr)
    );
    let journal_path: PathBuf = dir.path().join("sweep.jsonl");
    assert_eq!(
        SweepJournal::completed(&journal_path).unwrap().len(),
        5,
        "points 0..5 must be journaled before the kill"
    );

    let resumed = sweep_cmd("sweep.jsonl").arg("--resume-sweep").output().unwrap();
    assert_success(&resumed, "sweep resume");
    assert!(
        String::from_utf8_lossy(&resumed.stdout).contains("best:"),
        "resumed sweep must report a best point"
    );
    let resumed_map = by_label(&journal_path);
    assert_eq!(resumed_map, clean_map, "resumed journal scores differ from clean sweep");
}
