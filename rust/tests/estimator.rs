//! ISSUE 9 acceptance: the pluggable estimator layer is a pure
//! refactor for the four paper methods and a well-behaved extension
//! for the two new families.
//!
//! * PTQ/QAT/RAT/LOTION driven through the `Estimator` trait must be
//!   **bitwise-identical** to the pre-refactor driver. The reference
//!   here is an independent re-implementation of the legacy per-step
//!   loop (`{cast, loss_grad, fisher, penalty, opt.update}` written
//!   out by hand against the quant/optim primitives — no `Estimator`
//!   anywhere), checked against the engine's train entries on linreg,
//!   linear2 and the lm-tiny preset at `--threads 1` and auto.
//! * `anneal` at σ₀ = 0 collapses to QAT exactly, end to end through
//!   the `Trainer`.
//! * The scheduled families (`cge`, `anneal`) train to decreasing
//!   loss on lm-tiny, run as sweep grids at any `--sweep-workers`
//!   width, and a run killed mid-anneal via `LOTION_FAULTS` resumes
//!   bit-identical to the uninterrupted run — σ_t is a pure function
//!   of the absolute step, so no schedule state crosses the snapshot.

use anyhow::Result;
use lotion::config::{RunConfig, Schedule};
use lotion::coordinator::sweep::SweepPoint;
use lotion::coordinator::{
    DataSource, Evaluator, MetricsLogger, SweepResult, SweepRunner, Trainer,
};
use lotion::data::{ByteTokenizer, TokenBatcher, ZipfMarkovCorpus};
use lotion::experiments::common::synth_statics;
use lotion::quant::{cast_rr_seeded, cast_rtn_pool, lotion_penalty_and_grad_pool, QuantFormat};
use lotion::runtime::executor::value;
use lotion::runtime::native::optim::OptState;
use lotion::runtime::native::{
    EstSchedule, ModelSpec, NativeEngine, NativeFactory, NativeModel, OptKind, StepCtx,
    StepStreams,
};
use lotion::runtime::{Executor, Role, Value};
use lotion::tensor::HostTensor;
use lotion::util::faults::KILL_EXIT;
use lotion::util::pool::Pool;
use lotion::util::rng::Rng;
use lotion::util::tempdir::TempDir;
use std::collections::HashMap;
use std::path::Path;
use std::process::Command;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Bit-exact train-loss trace of a run.
fn trains(m: &MetricsLogger) -> Vec<String> {
    m.train_losses.iter().map(|(s, l)| format!("t{s}:{:016x}", l.to_bits())).collect()
}

// ---------------------------------------------------------------------------
// the legacy loop, reimplemented without the Estimator trait
// ---------------------------------------------------------------------------

/// Training state threaded across reference chunks.
struct RefState {
    params: Vec<Vec<f32>>,
    opt: OptState,
    scratch: Box<dyn std::any::Any>,
}

fn ref_init(model: &NativeModel, init_params: &[Vec<f32>]) -> RefState {
    let program = &*model.program;
    let pspecs = program.param_specs();
    let param_names: Vec<String> = pspecs.iter().map(|s| s.name.clone()).collect();
    let named: Vec<(String, Vec<f32>)> = model
        .opt
        .state_specs(&pspecs)
        .iter()
        .map(|s| (s.name.clone(), vec![0.0; s.elements()]))
        .collect();
    RefState {
        params: init_params.to_vec(),
        opt: OptState::unpack(model.opt, &param_names, &named).unwrap(),
        scratch: program.make_scratch(),
    }
}

/// One K-step chunk of the pre-refactor driver, written out by hand
/// against the quant/optim primitives: RTN cast for QAT, per-tensor
/// seeded RR cast for RAT, Fisher-weighted σ² penalty for LOTION,
/// nothing for PTQ (`fmt = None`). This is the behavioral spec the
/// `Estimator` plug-ins must reproduce bit for bit.
#[allow(clippy::too_many_arguments)]
fn ref_chunk(
    model: &NativeModel,
    st: &mut RefState,
    method: &str,
    fmt: Option<&QuantFormat>,
    statics: &[(String, Vec<f32>)],
    data: Option<&[i32]>,
    key: (u32, u32),
    lr: f32,
    lam_reg: f32,
) -> (Vec<f32>, Vec<f32>) {
    let program = &*model.program;
    let k = model.steps_per_call;
    let pool = Pool::serial();
    let param_names: Vec<String> =
        program.param_specs().iter().map(|s| s.name.clone()).collect();
    let quantized = program.quantized();
    let quant_idx: Vec<usize> = param_names
        .iter()
        .enumerate()
        .filter(|(_, n)| quantized.iter().any(|q| q.as_str() == n.as_str()))
        .map(|(i, _)| i)
        .collect();
    let chunk_seed = ((key.0 as u64) << 32) | key.1 as u64;
    let step_len = data.map(|d| d.len() / k).unwrap_or(0);
    let casts = fmt.is_some() && matches!(method, "qat" | "rat");
    let mut grads: Vec<Vec<f32>> = st.params.iter().map(|p| vec![0.0; p.len()]).collect();
    let mut wq: Vec<Vec<f32>> = if casts {
        st.params.iter().map(|p| vec![0.0; p.len()]).collect()
    } else {
        Vec::new()
    };
    let mut fisher: Vec<Vec<f32>> = if method == "lotion" && fmt.is_some() {
        quant_idx.iter().map(|&i| vec![0.0; st.params[i].len()]).collect()
    } else {
        Vec::new()
    };
    let (mut bases, mut totals) = (Vec::new(), Vec::new());
    for i in 0..k {
        let streams = StepStreams {
            data: Rng::stream_seed(chunk_seed, &[i as u64, 1]),
            round: Rng::stream_seed(chunk_seed, &[i as u64, 2]),
        };
        let ctx = StepCtx {
            statics,
            data: data.map(|d| &d[i * step_len..(i + 1) * step_len]),
            streams,
            pool: &pool,
        };
        let fwd: &[Vec<f32>] = if casts {
            let f = fmt.unwrap();
            for (w, p) in wq.iter_mut().zip(&st.params) {
                w.copy_from_slice(p);
            }
            if method == "qat" {
                for &pi in &quant_idx {
                    cast_rtn_pool(&mut wq[pi], f, &pool);
                }
            } else {
                for (qi, &pi) in quant_idx.iter().enumerate() {
                    let seed = Rng::stream_seed(streams.round, &[qi as u64]);
                    cast_rr_seeded(&mut wq[pi], f, seed, &pool);
                }
            }
            &wq
        } else {
            &st.params
        };
        let base = program.loss_grad(fwd, &ctx, st.scratch.as_mut(), &mut grads).unwrap();
        let mut total = base;
        if method == "lotion" {
            if let Some(f) = fmt {
                if !program.fisher_exact_into(&st.params, &ctx, &mut fisher).unwrap() {
                    st.opt.fisher_into(&quant_idx, &mut fisher).unwrap();
                }
                for (qi, &pi) in quant_idx.iter().enumerate() {
                    let (pen, pg) =
                        lotion_penalty_and_grad_pool(&st.params[pi], &fisher[qi], f, &pool);
                    total += lam_reg as f64 * pen;
                    for (g, p) in grads[pi].iter_mut().zip(&pg) {
                        *g += lam_reg * p;
                    }
                }
            }
        }
        st.opt.update(&mut st.params, &grads, lr).unwrap();
        bases.push(base as f32);
        totals.push(total as f32);
    }
    (bases, totals)
}

/// The same chunks through the engine's train entry (the traited
/// driver), chaining param/opt outputs back by name.
#[allow(clippy::too_many_arguments)]
fn engine_chunks(
    engine: &NativeEngine,
    model_name: &str,
    method: &str,
    fmt_key: &str,
    init_params: &[Vec<f32>],
    statics: &[(String, Value)],
    data_per_chunk: &[Vec<i32>],
    keys: &[(u32, u32)],
    lr: f32,
    lam_reg: f32,
) -> (Vec<Vec<f32>>, Vec<f32>, Vec<f32>) {
    let entry = engine.manifest().find_train(model_name, method, fmt_key).unwrap();
    let mut state: HashMap<String, Value> = HashMap::new();
    for (spec, p) in entry.input_specs(Role::Param).iter().zip(init_params) {
        state.insert(spec.name.clone(), value(HostTensor::from_f32(&spec.shape, p.clone())));
    }
    for spec in entry.input_specs(Role::Opt) {
        state.insert(spec.name.clone(), value(HostTensor::zeros(spec.dtype, &spec.shape)));
    }
    let (mut bases, mut totals) = (Vec::new(), Vec::new());
    for (c, &key) in keys.iter().enumerate() {
        let args: Vec<Value> = entry
            .inputs
            .iter()
            .map(|s| match s.role {
                Role::Param | Role::Opt => state[&s.name].clone(),
                Role::Static => {
                    statics.iter().find(|(n, _)| n == &s.name).unwrap_or_else(|| {
                        panic!("no static input named {:?}", s.name)
                    }).1.clone()
                }
                Role::Data => value(HostTensor::from_i32(&s.shape, data_per_chunk[c].clone())),
                Role::Key => value(HostTensor::from_u32(&[2], vec![key.0, key.1])),
                Role::Scalar if s.name == "lrs" => {
                    value(HostTensor::from_f32(&s.shape, vec![lr; s.elements()]))
                }
                Role::Scalar if s.name == "lam_reg" => {
                    value(HostTensor::from_f32(&s.shape, vec![lam_reg; s.elements()]))
                }
                _ => panic!("unexpected train input {:?} ({:?})", s.name, s.role),
            })
            .collect();
        let out = engine.call(entry, &args).unwrap();
        for (o, v) in entry.outputs.iter().zip(&out) {
            match o.role {
                Role::Param | Role::Opt => {
                    state.insert(o.name.clone(), v.clone());
                }
                Role::Metric if o.name == "base_losses" => bases.extend(v.as_f32()),
                Role::Metric if o.name == "total_losses" => totals.extend(v.as_f32()),
                _ => {}
            }
        }
    }
    let params: Vec<Vec<f32>> =
        entry.input_specs(Role::Param).iter().map(|s| state[&s.name].as_f32()).collect();
    (params, bases, totals)
}

/// Drive the four paper methods through both implementations and
/// compare parameters + loss streams bitwise, engine at `--threads 1`
/// and auto (the reference pool is serial; bit-identity across pool
/// widths is the backend's standing contract).
fn parity_case(
    model: NativeModel,
    model_name: &str,
    statics: Vec<(String, HostTensor)>,
    data_per_chunk: Vec<Vec<i32>>,
    keys: Vec<(u32, u32)>,
    lr: f32,
    lam_reg: f32,
) {
    let int4 = QuantFormat::int4();
    let statics_f32: Vec<(String, Vec<f32>)> =
        statics.iter().map(|(n, t)| (n.clone(), t.as_f32())).collect();
    let static_vals: Vec<(String, Value)> =
        statics.into_iter().map(|(n, t)| (n, value(t))).collect();
    // same key-seeded init on both sides
    let seed_engine = NativeEngine::with_models(&[model.clone()]).with_threads(1);
    let init = seed_engine.manifest().find_init(model_name).unwrap();
    let init_out =
        seed_engine.call(init, &[value(HostTensor::from_u32(&[2], vec![3, 5]))]).unwrap();
    let init_params: Vec<Vec<f32>> = init_out.iter().map(|v| v.as_f32()).collect();

    let cases: [(&str, &str, Option<&QuantFormat>); 4] = [
        ("ptq", "none", None),
        ("qat", "int4", Some(&int4)),
        ("rat", "int4", Some(&int4)),
        ("lotion", "int4", Some(&int4)),
    ];
    for (method, fmt_key, fmt) in cases {
        let mut st = ref_init(&model, &init_params);
        let (mut ref_bases, mut ref_totals) = (Vec::new(), Vec::new());
        for (c, &key) in keys.iter().enumerate() {
            let d = data_per_chunk.get(c).map(|v| v.as_slice());
            let (b, t) =
                ref_chunk(&model, &mut st, method, fmt, &statics_f32, d, key, lr, lam_reg);
            ref_bases.extend(b);
            ref_totals.extend(t);
        }
        for threads in [1usize, 0] {
            let engine = NativeEngine::with_models(&[model.clone()]).with_threads(threads);
            let (params, bases, totals) = engine_chunks(
                &engine,
                model_name,
                method,
                fmt_key,
                &init_params,
                &static_vals,
                &data_per_chunk,
                &keys,
                lr,
                lam_reg,
            );
            for (i, (a, b)) in st.params.iter().zip(&params).enumerate() {
                assert_eq!(
                    bits(a),
                    bits(b),
                    "{model_name}/{method}: param {i} diverges from the legacy loop \
                     (threads={threads})"
                );
            }
            assert_eq!(
                bits(&ref_bases),
                bits(&bases),
                "{model_name}/{method}: base losses diverge (threads={threads})"
            );
            assert_eq!(
                bits(&ref_totals),
                bits(&totals),
                "{model_name}/{method}: total losses diverge (threads={threads})"
            );
        }
    }
}

/// Parity on linreg: in-graph data, SGD, exact Gauss-Newton Fisher;
/// `d` large enough to engage the parallel cast/penalty kernels.
#[test]
fn estimators_match_legacy_loop_on_linreg() {
    let d = 40_000;
    let model = NativeModel::from_spec(ModelSpec::LinReg { d, batch: 16 }, OptKind::Sgd, 4);
    let (statics, _, _) = synth_statics(d, 13);
    parity_case(model, &format!("linreg_d{d}"), statics, vec![], vec![(7, 11), (7, 12)], 0.05, 1.0);
}

/// Parity on the rank-k quadratic testbed.
#[test]
fn estimators_match_legacy_loop_on_linear2() {
    let (d, k) = (12_000, 4);
    let model = NativeModel::from_spec(ModelSpec::Linear2 { d, k }, OptKind::Sgd, 4);
    let (statics, _, _) = synth_statics(d, 29);
    parity_case(
        model,
        &format!("linear2_d{d}_k{k}"),
        statics,
        vec![],
        vec![(7, 11), (7, 12)],
        0.2,
        1.0,
    );
}

/// Parity on the transformer preset: token data path, Adam, the
/// optimizer-moment Fisher fallback.
#[test]
fn estimators_match_legacy_loop_on_lm_tiny() {
    let model = NativeModel::lm("lm-tiny", OptKind::Adam).unwrap();
    let spec = model.program.train_data_spec(model.steps_per_call).unwrap();
    let tokens: Vec<i32> = (0..spec.elements()).map(|i| ((i * 131 + 7) % 256) as i32).collect();
    parity_case(model, "lm-tiny", vec![], vec![tokens], vec![(7, 11)], 3e-3, 10.0);
}

// ---------------------------------------------------------------------------
// the new families: collapse, learning, sweep sharding, crash-resume
// ---------------------------------------------------------------------------

/// `anneal` at σ₀ = 0 adds exactly zero noise before rounding, so a
/// full Trainer run must match QAT bit for bit — params and every
/// train loss.
#[test]
fn anneal_at_sigma_zero_matches_qat_through_the_trainer() {
    let run = |method: &str, sigma0: f64| {
        let engine = NativeEngine::with_models(&[NativeModel::from_spec(
            ModelSpec::LinReg { d: 256, batch: 64 },
            OptKind::Sgd,
            8,
        )])
        .with_threads(0);
        let mut cfg = RunConfig::default();
        cfg.model = "linreg_d256".into();
        cfg.method = method.into();
        cfg.format = "int4".into();
        cfg.eval_formats = vec!["int4".into()];
        cfg.steps = 16;
        cfg.lr = 0.05;
        cfg.lambda = 1.0;
        cfg.eval_every = 8;
        cfg.schedule = Schedule::Constant;
        cfg.seed = 5;
        cfg.est_schedule = EstSchedule::Constant;
        cfg.est_sigma0 = sigma0;
        let (statics, _, _) = synth_statics(256, 3);
        let mut trainer = Trainer::new(&engine, cfg, statics, DataSource::InGraph).unwrap();
        let mut eval = Evaluator::new(5);
        let mut metrics = MetricsLogger::in_memory();
        trainer.run(&mut eval, &mut metrics).unwrap();
        (bits(&trainer.state().fetch("w").unwrap().as_f32()), trains(&metrics))
    };
    let (wq, lq) = run("qat", 1.0);
    let (wa, la) = run("anneal", 0.0);
    assert_eq!(wq, wa, "anneal at sigma0=0 must collapse to QAT bitwise");
    assert_eq!(lq, la, "train-loss traces differ between qat and anneal at sigma0=0");
}

/// Acceptance: both new families train to decreasing loss on lm-tiny.
#[test]
fn scheduled_families_learn_on_lm_tiny() {
    for (method, sigma0, grad_scale) in [("cge", 1.0, 0.5), ("anneal", 0.5, 1.0)] {
        let model = NativeModel::lm("lm-tiny", OptKind::Adam).unwrap();
        let engine = NativeEngine::with_models(&[model]).with_threads(0);
        let mut cfg = RunConfig::default();
        cfg.model = "lm-tiny".into();
        cfg.method = method.into();
        cfg.format = "int4".into();
        cfg.eval_formats = vec!["int4".into()];
        cfg.steps = 24;
        cfg.lr = 3e-3;
        cfg.lambda = 1.0;
        cfg.eval_every = 24;
        cfg.schedule = Schedule::Constant;
        cfg.seed = 7;
        cfg.est_schedule = EstSchedule::Cosine;
        cfg.est_sigma0 = sigma0;
        cfg.est_grad_scale = grad_scale;
        let corpus = ZipfMarkovCorpus::generate(200_000, 512, 4, 7);
        let toks = ByteTokenizer::new().encode(&corpus.bytes);
        let batcher = TokenBatcher::new(toks, 8, 64, 0.05);
        let mut trainer =
            Trainer::new(&engine, cfg, vec![], DataSource::Tokens(batcher)).unwrap();
        let mut eval = Evaluator::new(5);
        let mut metrics = MetricsLogger::in_memory();
        trainer.run(&mut eval, &mut metrics).unwrap();
        let l = &metrics.train_losses;
        assert!(l.len() >= 8, "{method}: expected a full loss trace, got {}", l.len());
        let head: f64 = l[..4].iter().map(|(_, v)| v).sum::<f64>() / 4.0;
        let tail: f64 = l[l.len() - 4..].iter().map(|(_, v)| v).sum::<f64>() / 4.0;
        assert!(
            tail < head,
            "{method}: loss should decrease on lm-tiny (first4 {head:.4} -> last4 {tail:.4})"
        );
    }
}

/// Both families run as a sweep grid through the sharded runner —
/// results are bit-identical at any `--sweep-workers` width.
#[test]
fn scheduled_family_sweep_is_worker_count_invariant() {
    let factory = NativeFactory::new(
        vec![NativeModel::from_spec(ModelSpec::LinReg { d: 256, batch: 64 }, OptKind::Sgd, 8)],
        1,
    );
    let mk = |label: &str, method: &str, sched: EstSchedule, sigma0: f64, scale: f64| {
        let mut cfg = RunConfig::default();
        cfg.name = label.into();
        cfg.model = "linreg_d256".into();
        cfg.method = method.into();
        cfg.format = "int4".into();
        cfg.eval_formats = vec!["int4".into()];
        cfg.steps = 16;
        cfg.lr = 0.05;
        cfg.lambda = 1.0;
        cfg.eval_every = 8;
        cfg.schedule = Schedule::Constant;
        cfg.seed = 5;
        cfg.est_schedule = sched;
        cfg.est_sigma0 = sigma0;
        cfg.est_grad_scale = scale;
        SweepPoint::new(label, cfg)
    };
    let points = || {
        vec![
            mk("anneal_s0.5_cos", "anneal", EstSchedule::Cosine, 0.5, 1.0),
            mk("anneal_s1_cos", "anneal", EstSchedule::Cosine, 1.0, 1.0),
            mk("anneal_s1_lin", "anneal", EstSchedule::Linear, 1.0, 1.0),
            mk("cge_c0.5", "cge", EstSchedule::Constant, 1.0, 0.5),
        ]
    };
    let inputs = |_: &dyn Executor,
                  _: &RunConfig|
     -> Result<(Vec<(String, HostTensor)>, DataSource)> {
        let (statics, _, _) = synth_statics(256, 3);
        Ok((statics, DataSource::InGraph))
    };
    let fp = |rs: &[SweepResult]| -> Vec<String> {
        rs.iter().map(|r| format!("{}:{:016x}", r.label, r.score.to_bits())).collect()
    };
    let serial = SweepRunner::new(&factory, 1).run(points(), "int4", "rtn", &inputs).unwrap();
    let wide = SweepRunner::new(&factory, 3).run(points(), "int4", "rtn", &inputs).unwrap();
    assert!(serial.iter().all(|r| !r.diverged), "grid point diverged in the serial pass");
    assert_eq!(fp(&serial), fp(&wide), "sweep results differ across --sweep-workers");
}

// ---------------------------------------------------------------------------
// subprocess: kill mid-anneal, resume, compare to uninterrupted
// ---------------------------------------------------------------------------

/// `--set` overrides pinning a deterministic 24-step annealed run on
/// the default registry's linreg_d256 (K=8): cosine σ-schedule from
/// σ₀ = 0.5, so step 16 sits mid-anneal with σ_t strictly between
/// σ₀ and 0.
const ANNEAL_SETS: &[&str] = &[
    "--set", "train.steps=24",
    "--set", "eval.every=8",
    "--set", "train.schedule=constant",
    "--set", "train.lr=0.05",
    "--set", "train.lambda=1.0",
    "--set", "seed=5",
];

fn anneal_cmd(cwd: &Path, out: &str) -> Command {
    let mut c = Command::new(env!("CARGO_BIN_EXE_lotion-rs"));
    c.current_dir(cwd)
        .args(["train", "--backend", "native", "--method", "anneal"])
        .args(["--est-schedule", "cosine", "--est-sigma0", "0.5"])
        .args(ANNEAL_SETS)
        .args(["--ckpt-every", "8", "--out", out])
        .env_remove("LOTION_FAULTS")
        .env_remove("LOTION_THREADS")
        .env_remove("LOTION_CKPT_EVERY")
        .env_remove("LOTION_CKPT_DIR")
        .env_remove("LOTION_SWEEP_WORKERS");
    c
}

/// The metrics JSONL with the (nondeterministic) wall-clock field
/// stripped — every other field is bit-determined.
fn metrics_lines(path: &Path) -> Vec<String> {
    std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("reading {path:?}: {e}"))
        .lines()
        .map(|l| l.split(",\"wall_s\"").next().unwrap().to_string())
        .collect()
}

/// Schedule-resume bit-identity at the CLI: a run killed mid-anneal
/// by `LOTION_FAULTS=kill@step:16` exits with [`KILL_EXIT`], leaves a
/// resumable snapshot, and `--resume` completes it bit-identical to
/// the uninterrupted baselines — σ_t is recomputed from the absolute
/// step on the resumed side, never read from the snapshot.
#[test]
fn cli_kill_mid_anneal_and_resume_is_bit_identical() {
    let dir = TempDir::new();
    let a1 = anneal_cmd(dir.path(), "a1").env("LOTION_THREADS", "1").output().unwrap();
    assert!(
        a1.status.success(),
        "baseline anneal train (threads=1) failed: {}",
        String::from_utf8_lossy(&a1.stderr)
    );
    let a2 = anneal_cmd(dir.path(), "a2").output().unwrap();
    assert!(
        a2.status.success(),
        "baseline anneal train (threads=auto) failed: {}",
        String::from_utf8_lossy(&a2.stderr)
    );
    let final_a1 = std::fs::read(dir.path().join("a1/final.lotn")).unwrap();
    assert_eq!(
        final_a1,
        std::fs::read(dir.path().join("a2/final.lotn")).unwrap(),
        "annealed final checkpoint differs across LOTION_THREADS"
    );
    let lines_a1 = metrics_lines(&dir.path().join("a1/metrics.jsonl"));
    assert_eq!(lines_a1, metrics_lines(&dir.path().join("a2/metrics.jsonl")));

    let killed =
        anneal_cmd(dir.path(), "b").env("LOTION_FAULTS", "kill@step:16").output().unwrap();
    assert_eq!(
        killed.status.code(),
        Some(KILL_EXIT),
        "kill@step:16 should exit {KILL_EXIT}: {}",
        String::from_utf8_lossy(&killed.stderr)
    );
    assert!(dir.path().join("b/step000016.lotn").exists(), "snapshot missing after kill");
    assert!(!dir.path().join("b/final.lotn").exists(), "killed run must not finalize");

    // resume at a different thread width than the killed run; the σ
    // schedule must pick up at σ_16, not restart from σ₀
    let resumed = anneal_cmd(dir.path(), "b")
        .arg("--resume")
        .arg(dir.path().join("b"))
        .env("LOTION_THREADS", "1")
        .output()
        .unwrap();
    assert!(
        resumed.status.success(),
        "anneal resume failed: {}",
        String::from_utf8_lossy(&resumed.stderr)
    );
    assert_eq!(
        final_a1,
        std::fs::read(dir.path().join("b/final.lotn")).unwrap(),
        "resumed annealed run differs from uninterrupted"
    );
    assert_eq!(
        lines_a1,
        metrics_lines(&dir.path().join("b/metrics.jsonl")),
        "appended metrics JSONL differs from uninterrupted anneal baseline"
    );
}
