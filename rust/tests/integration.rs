//! End-to-end integration on the PJRT backend: load real AOT
//! artifacts, train, evaluate. Needs `--features pjrt` to compile and
//! `make artifacts` to run (skips gracefully otherwise). The native
//! backend's equivalent suite is `tests/native_backend.rs`.
#![cfg(feature = "pjrt")]

use lotion::config::RunConfig;
use lotion::coordinator::{DataSource, Evaluator, MetricsLogger, Trainer};
use lotion::data::{power_law_spectrum, sample_wstar, ByteTokenizer, TokenBatcher, ZipfMarkovCorpus};
use lotion::quant::{QuantFormat, Rounding};
use lotion::runtime::Engine;
use lotion::tensor::HostTensor;
use lotion::util::rng::Rng;
use std::path::Path;

fn engine() -> Option<Engine> {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built");
        return None;
    }
    Some(Engine::new(dir).expect("engine"))
}

fn linreg_statics(d: usize, seed: u64) -> Vec<(String, HostTensor)> {
    let mut rng = Rng::new(seed);
    vec![
        ("lam".into(), HostTensor::from_f32(&[d], power_law_spectrum(d, 1.1))),
        ("wstar".into(), HostTensor::from_f32(&[d], sample_wstar(d, &mut rng))),
    ]
}

fn linreg_cfg(method: &str) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.model = "linreg_d256".into();
    cfg.method = method.into();
    cfg.format = if method == "ptq" { "none".into() } else { "int4".into() };
    cfg.steps = 160;
    cfg.lr = 0.1;
    cfg.lambda = 1.0;
    cfg.eval_every = 80;
    cfg
}

#[test]
fn linreg_lotion_trains_and_beats_init() {
    let Some(engine) = engine() else { return };
    let cfg = linreg_cfg("lotion");
    let statics = linreg_statics(256, 3);
    let mut trainer =
        Trainer::new(&engine, cfg.clone(), statics, DataSource::InGraph).expect("trainer");
    let mut eval = Evaluator::new(0);
    let mut metrics = MetricsLogger::in_memory();

    let fmt = QuantFormat::int4();
    let v0 = eval.eval_cast(&trainer, Some(&fmt), Rounding::Rtn).unwrap();
    trainer.run(&mut eval, &mut metrics).unwrap();
    let v1 = eval.eval_cast(&trainer, Some(&fmt), Rounding::Rtn).unwrap();
    assert!(v1 < v0 * 0.8, "quantized val loss {v0} -> {v1}");
    assert_eq!(trainer.step, 160);
    // metrics got both train chunks and eval points
    assert!(!metrics.train_losses.is_empty());
    assert!(metrics.best_eval("int4", "rtn").is_some());
    assert!(metrics.best_eval("int4", "rr").is_some());
    assert!(metrics.final_eval("fp32", "none").is_some());
}

#[test]
fn all_four_methods_run_on_linreg() {
    let Some(engine) = engine() else { return };
    for method in ["ptq", "qat", "rat", "lotion"] {
        let mut cfg = linreg_cfg(method);
        cfg.steps = 32;
        cfg.eval_every = 32;
        let statics = linreg_statics(256, 5);
        let mut trainer =
            Trainer::new(&engine, cfg.clone(), statics, DataSource::InGraph).unwrap();
        let mut eval = Evaluator::new(1);
        let mut metrics = MetricsLogger::in_memory();
        trainer.run(&mut eval, &mut metrics).expect(method);
        assert!(metrics.final_eval("fp32", "none").unwrap().is_finite(), "{method}");
    }
}

#[test]
fn trainer_is_deterministic_per_seed() {
    let Some(engine) = engine() else { return };
    let run = |seed: u64| {
        let mut cfg = linreg_cfg("qat");
        cfg.steps = 24;
        cfg.seed = seed;
        let statics = linreg_statics(256, 7);
        let mut trainer = Trainer::new(&engine, cfg, statics, DataSource::InGraph).unwrap();
        let mut metrics = MetricsLogger::in_memory();
        for _ in 0..3 {
            trainer.chunk(&mut metrics).unwrap();
        }
        trainer.state().fetch("w").unwrap().as_f32()
    };
    assert_eq!(run(9), run(9));
    assert_ne!(run(9), run(10));
}

#[test]
fn lm_tiny_trains_on_corpus() {
    let Some(engine) = engine() else { return };
    let mut cfg = RunConfig::default();
    cfg.model = "lm-tiny".into();
    cfg.method = "lotion".into();
    cfg.format = "int4".into();
    cfg.steps = 16;
    cfg.lr = 3e-3;
    cfg.lambda = 10.0;
    cfg.eval_every = 16;

    let corpus = ZipfMarkovCorpus::generate(200_000, 512, 4, 1);
    let toks = ByteTokenizer::new().encode(&corpus.bytes);
    let batcher = TokenBatcher::new(toks, 8, 64, 0.1);
    let mut trainer =
        Trainer::new(&engine, cfg.clone(), vec![], DataSource::Tokens(batcher)).unwrap();
    let mut eval = Evaluator::new(2);
    let mut metrics = MetricsLogger::in_memory();
    trainer.run(&mut eval, &mut metrics).unwrap();

    // initial loss ~ ln(256) = 5.55; must improve within 16 steps
    let (_, first) = (metrics.train_losses[0].0, metrics.train_losses[0].1);
    let last = metrics.train_losses.last().unwrap().1;
    assert!(first > 4.0, "first={first}");
    assert!(last < first, "first={first} last={last}");
    // quantized eval tracks fp32 eval (at this early stage the INT4 cast
    // perturbs loss by well under 1 nat either way)
    let fp32 = metrics.final_eval("fp32", "none").unwrap();
    let q = metrics.final_eval("int4", "rtn").unwrap();
    assert!((q - fp32).abs() < 1.0, "fp32={fp32} int4={q}");
}

#[test]
fn engine_rejects_wrong_arity_and_missing_artifacts() {
    use lotion::runtime::Executor;
    let Some(engine) = engine() else { return };
    let entry = engine.manifest.find_eval("linreg_d256").unwrap();
    assert!(engine.call(entry, &[]).is_err());
    assert!(engine.manifest.get("no_such_artifact").is_err());
    assert!(engine.manifest.find_train("linreg_d256", "nope", "int4").is_err());
}
