//! End-to-end integration on the native pure-rust backend: no AOT
//! artifacts, no python, no PJRT — the whole coordinator stack
//! (trainer, evaluator with real RR/RTN eval casts, sweeps) against
//! `runtime::native`. This is the suite that keeps the default build
//! honest (DESIGN.md §3).

use lotion::config::{RunConfig, Schedule};
use lotion::coordinator::{sweep, DataSource, Evaluator, MetricsLogger, Trainer};
use lotion::data::synth::population_loss;
use lotion::data::{ByteTokenizer, TokenBatcher, ZipfMarkovCorpus};
use lotion::experiments::common::synth_statics;
use lotion::quant::{QuantFormat, Rounding};
use lotion::runtime::native::{
    LmConfig, LmProgram, ModelSpec, NativeEngine, NativeFactory, NativeModel, OptKind,
};
use lotion::runtime::Executor;
use std::sync::Arc;

fn linreg_cfg(method: &str, steps: usize) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.name = format!("native_{method}");
    cfg.model = "linreg_d256".into();
    cfg.method = method.into();
    cfg.format = if method == "ptq" { "none".into() } else { "int4".into() };
    cfg.eval_formats = vec!["int4".into()];
    cfg.steps = steps;
    cfg.lr = 0.1;
    cfg.lambda = 1.0;
    cfg.eval_every = steps;
    cfg.schedule = Schedule::Constant;
    cfg
}

/// The ISSUE's acceptance check: train linreg for ~50 steps with LOTION
/// on the native backend and watch both the train loss and the
/// quantized validation loss drop.
#[test]
fn linreg_lotion_50_steps_loss_decreases() {
    let engine = NativeEngine::new();
    let cfg = linreg_cfg("lotion", 56); // 7 chunks of K=8
    let (statics, _, _) = synth_statics(256, 3);
    let mut trainer = Trainer::new(&engine, cfg.clone(), statics, DataSource::InGraph).unwrap();
    let mut eval = Evaluator::new(0);
    let mut metrics = MetricsLogger::in_memory();

    let fmt = QuantFormat::int4();
    let v0 = eval.eval_cast(&trainer, Some(&fmt), Rounding::Rtn).unwrap();
    trainer.run(&mut eval, &mut metrics).unwrap();
    let v1 = eval.eval_cast(&trainer, Some(&fmt), Rounding::Rtn).unwrap();
    assert_eq!(trainer.step, 56);
    assert!(v1 < v0 * 0.8, "quantized val loss {v0} -> {v1}");

    let first = metrics.train_losses.first().unwrap().1;
    let last = metrics.train_losses.last().unwrap().1;
    assert!(last < first, "train loss {first} -> {last}");
    // the full eval battery ran: fp32 + int4 under both roundings
    assert!(metrics.final_eval("fp32", "none").is_some());
    assert!(metrics.final_eval("int4", "rtn").is_some());
    assert!(metrics.final_eval("int4", "rr").is_some());
}

#[test]
fn all_four_methods_run_on_native_linreg() {
    let engine = NativeEngine::new();
    for method in ["ptq", "qat", "rat", "lotion"] {
        let cfg = linreg_cfg(method, 32);
        let (statics, _, _) = synth_statics(256, 5);
        let mut trainer =
            Trainer::new(&engine, cfg.clone(), statics, DataSource::InGraph).unwrap();
        let mut eval = Evaluator::new(1);
        let mut metrics = MetricsLogger::in_memory();
        trainer.run(&mut eval, &mut metrics).expect(method);
        assert!(metrics.final_eval("fp32", "none").unwrap().is_finite(), "{method}");
        assert!(metrics.final_eval("int4", "rr").unwrap().is_finite(), "{method}");
    }
}

#[test]
fn native_trainer_is_deterministic_per_seed() {
    let engine = NativeEngine::new();
    let run = |seed: u64| {
        let mut cfg = linreg_cfg("rat", 24);
        cfg.seed = seed;
        let (statics, _, _) = synth_statics(256, 7);
        let mut trainer = Trainer::new(&engine, cfg, statics, DataSource::InGraph).unwrap();
        let mut metrics = MetricsLogger::in_memory();
        for _ in 0..3 {
            trainer.chunk(&mut metrics).unwrap();
        }
        trainer.state().fetch("w").unwrap().as_f32()
    };
    assert_eq!(run(9), run(9));
    assert_ne!(run(9), run(10));
}

/// FP32 eval must agree with the host-side closed form — the native
/// eval program and `population_loss` compute the same quantity.
#[test]
fn native_eval_matches_population_loss() {
    let engine = NativeEngine::new();
    let cfg = linreg_cfg("lotion", 16);
    let (statics, lam, wstar) = synth_statics(256, 11);
    let mut trainer = Trainer::new(&engine, cfg.clone(), statics, DataSource::InGraph).unwrap();
    let mut eval = Evaluator::new(2);
    let mut metrics = MetricsLogger::in_memory();
    trainer.run(&mut eval, &mut metrics).unwrap();
    let w = trainer.state().fetch("w").unwrap().as_f32();
    let direct = population_loss(&w, &wstar, &lam);
    let via_eval = eval.eval_cast(&trainer, None, Rounding::Rtn).unwrap();
    assert!(
        (direct - via_eval).abs() < 1e-5 * direct.abs().max(1e-6),
        "direct={direct} eval={via_eval}"
    );
}

#[test]
fn linear2_trains_on_native_backend() {
    let engine = NativeEngine::with_models(&[NativeModel::from_spec(
        ModelSpec::Linear2 { d: 128, k: 4 },
        OptKind::Sgd,
        8,
    )]);
    let mut cfg = RunConfig::default();
    cfg.model = "linear2_d128_k4".into();
    cfg.method = "lotion".into();
    cfg.format = "int4".into();
    cfg.eval_formats = vec!["int4".into()];
    cfg.steps = 64;
    cfg.lr = 0.3;
    cfg.lambda = 1.0;
    cfg.eval_every = 64;
    cfg.schedule = Schedule::Constant;
    let (statics, _, _) = synth_statics(128, 21);
    let mut trainer = Trainer::new(&engine, cfg.clone(), statics, DataSource::InGraph).unwrap();
    let mut eval = Evaluator::new(0);
    let mut metrics = MetricsLogger::in_memory();
    let v0 = eval.eval_cast(&trainer, None, Rounding::Rtn).unwrap();
    trainer.run(&mut eval, &mut metrics).unwrap();
    let v1 = eval.eval_cast(&trainer, None, Rounding::Rtn).unwrap();
    assert!(v1 < v0, "linear2 fp32 val loss {v0} -> {v1}");
    // both quantized tensors (w1, w2) survive the eval casts
    assert!(metrics.final_eval("int4", "rtn").unwrap().is_finite());
}

#[test]
fn adam_trains_linreg_on_native_backend() {
    let engine = NativeEngine::with_models(&[NativeModel::from_spec(
        ModelSpec::LinReg { d: 64, batch: 32 },
        OptKind::Adam,
        8,
    )]);
    let train = engine.manifest().find_train("linreg_d64", "lotion", "int4").unwrap();
    assert_eq!(train.optimizer, "adam");
    // adam state tensors ride along in canonical order: m.w, t, v.w
    let opt_names: Vec<&str> = train
        .inputs
        .iter()
        .filter(|s| s.role == lotion::runtime::Role::Opt)
        .map(|s| s.name.as_str())
        .collect();
    assert_eq!(opt_names, vec!["m.w", "t", "v.w"]);

    let mut cfg = linreg_cfg("lotion", 48);
    cfg.model = "linreg_d64".into();
    cfg.lr = 0.05;
    let (statics, _, _) = synth_statics(64, 13);
    let mut trainer = Trainer::new(&engine, cfg.clone(), statics, DataSource::InGraph).unwrap();
    let mut eval = Evaluator::new(0);
    let mut metrics = MetricsLogger::in_memory();
    trainer.run(&mut eval, &mut metrics).unwrap();
    let first = metrics.train_losses.first().unwrap().1;
    let last = metrics.train_losses.last().unwrap().1;
    assert!(last < first, "adam train loss {first} -> {last}");
    // the step counter advanced with the run
    assert_eq!(trainer.state().fetch("t").unwrap().scalar_to_f32(), 48.0);
}

/// A micro LM engine + token pipeline for the integration tests: a
/// CPU-tiny config keeps debug-mode runtime low while exercising the
/// full interpreter (attention, SwiGLU, Adam, data-role batches).
fn lm_micro_engine() -> NativeEngine {
    let program = LmProgram::new(
        "lm-micro",
        LmConfig { vocab: 256, d_model: 32, n_layers: 2, n_heads: 2, seq_len: 32 },
        4,
        2,
    )
    .unwrap();
    NativeEngine::with_models(&[NativeModel {
        program: Arc::new(program),
        opt: OptKind::Adam,
        steps_per_call: 5,
    }])
}

fn lm_batcher(seed: u64) -> TokenBatcher {
    let corpus = ZipfMarkovCorpus::generate(60_000, 256, 4, seed);
    let toks = ByteTokenizer::new().encode(&corpus.bytes);
    TokenBatcher::new(toks, 4, 32, 0.1)
}

/// ISSUE 3 acceptance: 50 steps of the transformer interpreter drop
/// the train loss for all four methods (PTQ/QAT/RAT/LOTION), with the
/// full eval battery running on the quantized subset.
#[test]
fn lm_all_four_methods_train_loss_decreases() {
    let engine = lm_micro_engine();
    for method in ["ptq", "qat", "rat", "lotion"] {
        let mut cfg = RunConfig::default();
        cfg.name = format!("lm_micro_{method}");
        cfg.model = "lm-micro".into();
        cfg.method = method.into();
        cfg.format = if method == "ptq" { "none".into() } else { "int8".into() };
        cfg.eval_formats = vec!["int8".into()];
        cfg.steps = 50;
        cfg.lr = 3e-3;
        cfg.lambda = 30.0;
        cfg.eval_every = 50;
        cfg.schedule = Schedule::Constant;
        cfg.seed = 11;
        let mut trainer =
            Trainer::new(&engine, cfg.clone(), vec![], DataSource::Tokens(lm_batcher(13)))
                .unwrap();
        let mut eval = Evaluator::new(1);
        let mut metrics = MetricsLogger::in_memory();
        trainer.run(&mut eval, &mut metrics).expect(method);
        assert_eq!(trainer.step, 50, "{method}");
        let first = metrics.train_losses.first().unwrap().1;
        let last = metrics.train_losses.last().unwrap().1;
        assert!(last < first, "{method}: train loss {first} -> {last}");
        // near-uniform start: mean CE of the first chunk is ~ln(256)
        assert!(first > 4.0 && first < 7.0, "{method}: odd initial loss {first}");
        assert!(metrics.final_eval("fp32", "none").unwrap().is_finite(), "{method}");
        assert!(metrics.final_eval("int8", "rr").unwrap().is_finite(), "{method}");
    }
}

/// The LM evaluator path casts only the quantized subset: norm gains
/// and the embedding stay FP32, so an aggressive format still yields a
/// finite, comparable loss.
#[test]
fn lm_eval_cast_touches_only_quantized_tensors() {
    let engine = lm_micro_engine();
    let mut cfg = RunConfig::default();
    cfg.model = "lm-micro".into();
    cfg.method = "lotion".into();
    cfg.format = "int4".into();
    cfg.steps = 5;
    cfg.eval_every = 5;
    cfg.schedule = Schedule::Constant;
    let mut trainer =
        Trainer::new(&engine, cfg.clone(), vec![], DataSource::Tokens(lm_batcher(17))).unwrap();
    let mut metrics = MetricsLogger::in_memory();
    trainer.chunk(&mut metrics).unwrap();
    assert!(trainer.quantized_keys().contains(&"lm_head".to_string()));
    assert!(!trainer.quantized_keys().contains(&"embed".to_string()));
    let mut eval = Evaluator::new(2);
    let fp32 = eval.eval_cast(&trainer, None, Rounding::Rtn).unwrap();
    let int4 = eval.eval_cast(&trainer, Some(&QuantFormat::int4()), Rounding::Rtn).unwrap();
    assert!(fp32.is_finite() && int4.is_finite());
    // casting perturbs the loss but must not blow it up at init scale
    assert!((int4 - fp32).abs() < 2.0, "fp32={fp32} int4={int4}");
}

#[test]
fn lr_sweep_runs_on_native_backend() {
    let factory = NativeFactory::with_default_models(1);
    let cfg = linreg_cfg("lotion", 16);
    let results = sweep::lr_sweep(
        &factory,
        1,
        &cfg,
        &[0.02, 0.2],
        "int4",
        "rtn",
        &|_: &dyn Executor, _: &RunConfig| {
            let (statics, _, _) = synth_statics(256, 3);
            Ok((statics, DataSource::InGraph))
        },
    )
    .unwrap();
    assert_eq!(results.len(), 2);
    assert!(results.iter().all(|r| !r.diverged));
    assert!(sweep::best(&results).is_some());
    // the larger LR should fit this easy quadratic better in 16 steps
    assert!(results[1].score < results[0].score);
}
