//! ISSUE 8 acceptance: the serving engine end to end.
//!
//! * the quantized decode path never touches a dense weight buffer —
//!   the process-wide dense-decode counter is flat across a whole
//!   packed serve run, for every registered packed format;
//! * completions are bitwise-identical across kernel thread counts
//!   (the serve determinism contract on top of the threaded backend's
//!   bit-stability);
//! * the `serve --weights` seam roundtrips: weights written to a
//!   `.lotn` checkpoint and read back produce the exact completions of
//!   the in-memory originals.
//!
//! Tests that read the dense-decode counter serialize on one lock —
//! the counter is process-wide and cargo runs this binary's tests on
//! parallel threads.

use lotion::checkpoint::Checkpoint;
use lotion::coordinator::serve::{serve_synthetic, ServeConfig};
use lotion::formats::json::Json;
use lotion::quant::packed::dense_decode_count;
use lotion::runtime::executor::value;
use lotion::runtime::native::NativeFactory;
use lotion::runtime::ExecutorFactory;
use lotion::tensor::HostTensor;
use lotion::util::tempdir::TempDir;
use std::sync::Mutex;

static COUNTER_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    COUNTER_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// lm-tiny FP32 masters through the init entry, named per param spec.
fn lm_tiny_weights(factory: &dyn ExecutorFactory) -> Vec<(String, HostTensor)> {
    let e = factory.spawn().unwrap();
    let init = e.manifest().find_init("lm-tiny").unwrap().clone();
    let key = value(HostTensor::from_u32(&[2], vec![7, 11]));
    let out = e.call(&init, &[key]).unwrap();
    init.outputs.iter().zip(out).map(|(s, v)| (s.name.clone(), v.as_ref().clone())).collect()
}

fn cfg(format: &str) -> ServeConfig {
    ServeConfig {
        format: format.into(),
        engines: 2,
        max_batch: 2,
        requests: 5,
        prompt_len: 6,
        gen_len: 4,
        temperature: 0.9,
        ..ServeConfig::default()
    }
}

/// The tentpole's perf invariant: serving from packed weights runs
/// prefill and every decode step through the fused packed GEMV — zero
/// dense decodes, for per-tensor and per-block formats alike.
#[test]
fn quantized_serve_never_decodes_dense() {
    let _g = lock();
    let factory = NativeFactory::with_default_models(1);
    let weights = lm_tiny_weights(&factory);
    for fmt in ["int4", "int8", "fp4", "int4@64"] {
        let before = dense_decode_count();
        let r = serve_synthetic(&factory, &weights, &cfg(fmt)).unwrap();
        assert_eq!(
            dense_decode_count(),
            before,
            "{fmt}: serve must stay on the fused packed path"
        );
        assert_eq!(r.completions.len(), 5);
        assert_eq!(r.generated_tokens(), 5 * 4);
        for c in &r.completions {
            assert!(c.tokens.iter().all(|&t| (0..256).contains(&t)), "{fmt}: token out of vocab");
        }
    }
}

/// Kernel thread count moves wall clock only, never tokens: the same
/// workload on 1-thread and auto-width engines is bitwise-identical,
/// dense and packed.
#[test]
fn completions_are_invariant_across_thread_counts() {
    let _g = lock();
    for fmt in ["none", "int4@64"] {
        let f1 = NativeFactory::with_default_models(1);
        let weights = lm_tiny_weights(&f1);
        let t1 = serve_synthetic(&f1, &weights, &cfg(fmt)).unwrap();
        let fall = NativeFactory::with_default_models(0);
        let tall = serve_synthetic(&fall, &weights, &cfg(fmt)).unwrap();
        assert_eq!(t1.completions.len(), tall.completions.len());
        for (a, b) in t1.completions.iter().zip(&tall.completions) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.tokens, b.tokens, "{fmt}: request {} diverged across thread counts", a.id);
        }
    }
}

/// The `serve --weights final.lotn` seam: checkpointed weights named
/// per the decode entry's param specs serve the exact same text as the
/// in-memory masters they were saved from.
#[test]
fn serve_from_checkpoint_weights_roundtrips() {
    let _g = lock();
    let factory = NativeFactory::with_default_models(1);
    let weights = lm_tiny_weights(&factory);
    let direct = serve_synthetic(&factory, &weights, &cfg("int4")).unwrap();

    let dir = TempDir::new();
    let path = dir.path().join("final.lotn");
    let mut ckpt = Checkpoint::new(Json::obj(vec![("model", Json::str("lm-tiny"))]));
    for (name, t) in &weights {
        ckpt.push(name, t.clone());
    }
    ckpt.save(&path).unwrap();

    let loaded = Checkpoint::load(&path).unwrap();
    // the seam's contract: every decode param resolves by name
    let probe = factory.spawn().unwrap();
    let entry = probe.manifest().find_decode("lm-tiny", "int4").unwrap().clone();
    let restored: Vec<(String, HostTensor)> = entry
        .input_specs(lotion::runtime::Role::Param)
        .into_iter()
        .map(|s| (s.name.clone(), loaded.get(&s.name).expect("checkpointed param").clone()))
        .collect();
    drop(probe);

    let replayed = serve_synthetic(&factory, &restored, &cfg("int4")).unwrap();
    assert_eq!(direct.completions.len(), replayed.completions.len());
    for (a, b) in direct.completions.iter().zip(&replayed.completions) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.tokens, b.tokens, "request {} diverged after checkpoint roundtrip", a.id);
    }
}
