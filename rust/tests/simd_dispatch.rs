//! ISSUE 6 acceptance: the runtime-dispatched SIMD kernels and the
//! fused block-dequant eval path change throughput only, never bits.
//!
//! * training + eval output is bit-identical with the tier pinned to
//!   scalar vs auto-detected (AVX2/NEON where the CPU has them);
//! * the fused `eval_q` route reproduces host-side `cast_rtn` through
//!   the plain eval entry bit-for-bit, on the LM without ever decoding
//!   a packed tensor to a dense f32 buffer;
//! * the evaluator's fused RTN route leaves its RNG stream exactly
//!   where the host-cast route would, so later RR evals are unmoved.
//!
//! Every test here serializes on one lock: the tier override and the
//! dense-decode counter are process-wide, and cargo runs integration
//! tests in this binary on parallel threads.

use lotion::config::{RunConfig, Schedule};
use lotion::coordinator::{DataSource, Evaluator, MetricsLogger, Trainer};
use lotion::data::{ByteTokenizer, TokenBatcher, ZipfMarkovCorpus};
use lotion::experiments::common::synth_statics;
use lotion::quant::packed::dense_decode_count;
use lotion::quant::{cast, cast_rtn, QuantFormat, Rounding};
use lotion::runtime::executor::value;
use lotion::runtime::native::{LmConfig, LmProgram, ModelSpec, NativeEngine, NativeModel, OptKind};
use lotion::tensor::HostTensor;
use lotion::util::rng::Rng;
use lotion::util::simd::{set_global_simd, SimdTier};
use std::sync::{Arc, Mutex};

/// Serializes every test in this binary: `set_global_simd` and the
/// dense-decode counter are process-wide state.
static GLOBAL_STATE_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    GLOBAL_STATE_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn bits(t: &HostTensor) -> Vec<u32> {
    t.as_f32().iter().map(|v| v.to_bits()).collect()
}

/// A small LM engine whose dims leave remainder lanes (44 % 8 != 0)
/// and edge tiles (44 % TILE_N != 0), so the vector kernels' tail
/// paths are exercised, with a trainer a couple of chunks in.
fn lm_trainer(engine: &NativeEngine) -> Trainer<'_> {
    let mut cfg = RunConfig::default();
    cfg.model = "lm-simd-test".into();
    cfg.method = "lotion".into();
    cfg.format = "int4".into();
    cfg.steps = 8;
    cfg.lr = 3e-3;
    cfg.lambda = 30.0;
    cfg.eval_every = 8;
    cfg.schedule = Schedule::Constant;
    cfg.seed = 5;
    let corpus = ZipfMarkovCorpus::generate(30_000, 256, 4, 9);
    let toks = ByteTokenizer::new().encode(&corpus.bytes);
    let batcher = TokenBatcher::new(toks, 4, 32, 0.1);
    let mut trainer = Trainer::new(engine, cfg, vec![], DataSource::Tokens(batcher)).unwrap();
    let mut metrics = MetricsLogger::in_memory();
    trainer.chunk(&mut metrics).unwrap();
    trainer
}

fn lm_engine() -> NativeEngine {
    let program = LmProgram::new(
        "lm-simd-test",
        LmConfig { vocab: 256, d_model: 44, n_layers: 2, n_heads: 2, seq_len: 32 },
        4,
        2,
    )
    .unwrap();
    NativeEngine::with_models(&[NativeModel {
        program: Arc::new(program),
        opt: OptKind::Adam,
        steps_per_call: 4,
    }])
}

/// One short linreg run at a forced tier; returns final param bits,
/// the train-loss trace, and an RTN + an RR eval.
fn run_linreg(tier: Option<SimdTier>) -> (Vec<u32>, Vec<(usize, f64)>, f64, f64) {
    set_global_simd(tier);
    let d = 40_000;
    let engine = NativeEngine::with_models(&[NativeModel::from_spec(
        ModelSpec::LinReg { d, batch: 16 },
        OptKind::Sgd,
        4,
    )]);
    let mut cfg = RunConfig::default();
    cfg.model = format!("linreg_d{d}");
    cfg.method = "lotion".into();
    cfg.format = "int4".into();
    cfg.steps = 8;
    cfg.lr = 0.05;
    cfg.lambda = 1.0;
    cfg.eval_every = 8;
    cfg.schedule = Schedule::Constant;
    cfg.seed = 7;
    let (statics, _, _) = synth_statics(d, 13);
    let mut trainer = Trainer::new(&engine, cfg, statics, DataSource::InGraph).unwrap();
    let mut metrics = MetricsLogger::in_memory();
    for _ in 0..2 {
        trainer.chunk(&mut metrics).unwrap();
    }
    let params = bits(&trainer.state().fetch("w").unwrap());
    let mut eval = Evaluator::new(3);
    let rtn = eval.eval_cast(&trainer, Some(&QuantFormat::int4()), Rounding::Rtn).unwrap();
    let rr = eval.eval_cast(&trainer, Some(&QuantFormat::int4()), Rounding::Rr).unwrap();
    set_global_simd(None);
    (params, metrics.train_losses.clone(), rtn, rr)
}

#[test]
fn linreg_training_is_bit_identical_across_simd_tiers() {
    let _g = lock();
    let (ps, ls, rtns, rrs) = run_linreg(Some(SimdTier::Scalar));
    let (pa, la, rtna, rra) = run_linreg(None);
    assert_eq!(ps, pa, "params differ between scalar and auto tiers");
    for ((s1, v1), (s2, v2)) in ls.iter().zip(&la) {
        assert_eq!(s1, s2);
        assert_eq!(v1.to_bits(), v2.to_bits(), "loss differs at step {s1}");
    }
    assert_eq!(rtns.to_bits(), rtna.to_bits(), "RTN eval differs");
    assert_eq!(rrs.to_bits(), rra.to_bits(), "RR eval differs");
}

#[test]
fn lm_training_is_bit_identical_across_simd_tiers() {
    let _g = lock();
    let run = |tier: Option<SimdTier>| {
        set_global_simd(tier);
        let engine = lm_engine();
        let trainer = lm_trainer(&engine);
        let params: Vec<Vec<u32>> = trainer
            .state()
            .names
            .iter()
            .map(|n| bits(&trainer.state().fetch(n).unwrap()))
            .collect();
        let mut eval = Evaluator::new(3);
        let rtn = eval.eval_cast(&trainer, Some(&QuantFormat::int4()), Rounding::Rtn).unwrap();
        let rr = eval.eval_cast(&trainer, Some(&QuantFormat::int4()), Rounding::Rr).unwrap();
        set_global_simd(None);
        (params, rtn, rr)
    };
    let (ps, rtns, rrs) = run(Some(SimdTier::Scalar));
    let (pa, rtna, rra) = run(None);
    assert_eq!(ps, pa, "LM params differ between scalar and auto tiers");
    assert_eq!(rtns.to_bits(), rtna.to_bits(), "LM RTN eval differs");
    assert_eq!(rrs.to_bits(), rra.to_bits(), "LM RR eval differs");
}

/// The LM's fused eval consumes packed weights in place: bitwise the
/// host-cast loss, and **zero** dense decodes — the ISSUE 6 gate that
/// the fused path allocates no full-f32 `wq` buffer.
#[test]
fn lm_fused_eval_matches_host_cast_without_dense_decode() {
    let _g = lock();
    let engine = lm_engine();
    let trainer = lm_trainer(&engine);
    let ke = trainer.session.eval_entry().eval_batches.max(1);
    let chunk = match &trainer.data {
        DataSource::Tokens(b) => value(b.val_chunk(ke, &mut Rng::new(11))),
        DataSource::InGraph => unreachable!("lm consumes tokens"),
    };
    let fmt = QuantFormat::parse("int4", 0).unwrap();
    let quantized = trainer.quantized_keys().to_vec();
    let host = trainer
        .session
        .eval_loss(Some(chunk.clone()), &mut |spec, v| {
            Ok(if quantized.iter().any(|k| k == &spec.name) {
                let mut wq = v.as_f32();
                cast_rtn(&mut wq, &fmt);
                value(HostTensor::from_f32(&v.shape, wq))
            } else {
                v.clone()
            })
        })
        .unwrap();
    let before = dense_decode_count();
    let fused = trainer
        .session
        .eval_loss_quantized("int4", Some(chunk))
        .unwrap()
        .expect("native eval_q entry");
    assert_eq!(
        dense_decode_count(),
        before,
        "the LM fused eval path decoded a packed tensor to dense f32"
    );
    assert_eq!(fused.to_bits(), host.to_bits(), "fused {fused} vs host-cast {host}");
}

/// Programs without a fused override (the testbeds) fall back to the
/// default dense decode — which is what the counter counts, proving
/// the zero-decode assertion above has teeth.
#[test]
fn default_packed_eval_decodes_and_is_counted() {
    let _g = lock();
    let engine = NativeEngine::with_models(&[NativeModel::from_spec(
        ModelSpec::LinReg { d: 256, batch: 16 },
        OptKind::Sgd,
        4,
    )]);
    let cfg = RunConfig::default();
    let (statics, _, _) = synth_statics(256, 13);
    let trainer = Trainer::new(&engine, cfg, statics, DataSource::InGraph).unwrap();
    let before = dense_decode_count();
    let fused = trainer.session.eval_loss_quantized("int4", None).unwrap();
    assert!(fused.is_some());
    assert!(dense_decode_count() > before, "default val_loss_packed must decode");
}

/// The evaluator's fused RTN route must leave `self.rng` exactly where
/// the legacy host-cast route would, so RR evals issued afterwards
/// draw identical noise either way.
#[test]
fn fused_rtn_route_keeps_the_eval_rng_stream_aligned() {
    let _g = lock();
    let engine = NativeEngine::new();
    let cfg = RunConfig::default();
    let (statics, _, _) = synth_statics(256, 13);
    let trainer = Trainer::new(&engine, cfg, statics, DataSource::InGraph).unwrap();
    let fmt = QuantFormat::int4();
    let quantized = trainer.quantized_keys().to_vec();

    let mut ev_fused = Evaluator::new(3);
    let mut ev_host = Evaluator::new(3);
    // fused route (eval_cast lands on eval_q for per-tensor RTN)
    let rtn_fused = ev_fused.eval_cast(&trainer, Some(&fmt), Rounding::Rtn).unwrap();
    // legacy route, forking the evaluator RNG per quantized param
    let rng = &mut ev_host.rng;
    let rtn_host = trainer
        .session
        .eval_loss(None, &mut |spec, v| {
            Ok(if quantized.iter().any(|k| k == &spec.name) {
                let mut host = v.as_ref().clone();
                let mut r = rng.fork(1);
                host.map_f32_inplace(|w| cast(w, &fmt, Rounding::Rtn, &mut r));
                value(host)
            } else {
                v.clone()
            })
        })
        .unwrap();
    assert_eq!(rtn_fused.to_bits(), rtn_host.to_bits());
    // the streams must agree *after* the RTN evals too
    let rr_fused = ev_fused.eval_cast(&trainer, Some(&fmt), Rounding::Rr).unwrap();
    let rr_host = ev_host.eval_cast(&trainer, Some(&fmt), Rounding::Rr).unwrap();
    assert_eq!(rr_fused.to_bits(), rr_host.to_bits(), "RR stream diverged after fused RTN");
}
