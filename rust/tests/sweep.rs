//! ISSUE 5 acceptance: the sharded sweep runner is **bit-identical to
//! the serial path at any `--sweep-workers` setting**, for both the
//! synthetic testbeds and the transformer LM. Each grid point is an
//! independent run (own counter-derived seed, inputs rebuilt per point
//! on the worker's factory-spawned engine), so the worker pool only
//! decides *which thread* runs a point — never what it computes.
//!
//! CI runs this suite at the default widths and oversubscribed
//! (`LOTION_SWEEP_WORKERS=8` × `LOTION_THREADS=16` on a smaller box),
//! which shakes out cross-engine races that hide at natural widths.

use anyhow::Result;
use lotion::config::{RunConfig, Schedule};
use lotion::coordinator::sweep::{self, lr_sweep, SweepPoint, SweepRunner};
use lotion::coordinator::{DataSource, SweepResult};
use lotion::data::{ByteTokenizer, TokenBatcher, ZipfMarkovCorpus};
use lotion::experiments::common::synth_statics;
use lotion::runtime::native::{LmConfig, LmProgram, ModelSpec, NativeFactory, NativeModel, OptKind};
use lotion::runtime::{Executor, ExecutorFactory};
use lotion::tensor::HostTensor;
use std::sync::Arc;

/// Everything observable about a sweep, bit-exact: per-point label,
/// score bits, divergence flag, train-loss trace and eval curve.
fn fingerprint(results: &[SweepResult]) -> Vec<String> {
    results
        .iter()
        .map(|r| {
            let mut s = format!("{} {:016x} {}", r.label, r.score.to_bits(), r.diverged);
            for &(step, l) in &r.metrics.train_losses {
                s.push_str(&format!(" t{step}:{:016x}", l.to_bits()));
            }
            for p in &r.metrics.eval_points {
                s.push_str(&format!(
                    " e{}:{}:{}:{:016x}",
                    p.step,
                    p.format,
                    p.rounding,
                    p.val_loss.to_bits()
                ));
            }
            s
        })
        .collect()
}

fn linreg_factory() -> NativeFactory {
    // per-engine threads 0 = auto (LOTION_THREADS), so the CI
    // oversubscription lane multiplies sweep workers by kernel threads
    NativeFactory::new(
        vec![NativeModel::from_spec(ModelSpec::LinReg { d: 256, batch: 64 }, OptKind::Sgd, 8)],
        0,
    )
}

fn linreg_base_cfg() -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.name = "sweep_test".into();
    cfg.model = "linreg_d256".into();
    cfg.method = "lotion".into();
    cfg.format = "int4".into();
    cfg.eval_formats = vec!["int4".into()];
    cfg.steps = 16;
    cfg.lambda = 1.0;
    cfg.eval_every = 16;
    cfg.schedule = Schedule::Constant;
    cfg.seed = 5;
    cfg
}

fn linreg_inputs(
    _: &dyn Executor,
    _: &RunConfig,
) -> Result<(Vec<(String, HostTensor)>, DataSource)> {
    let (statics, _, _) = synth_statics(256, 3);
    Ok((statics, DataSource::InGraph))
}

/// ISSUE 5 acceptance criterion: an 8-LR grid over linreg returns
/// bit-identical scores/metrics at `--sweep-workers 1` and `4` (and an
/// uneven width, and the env-resolved width).
#[test]
fn sharded_linreg_sweep_is_bit_identical_to_serial() {
    let factory = linreg_factory();
    let cfg = linreg_base_cfg();
    let lrs: Vec<f64> = (1..=8).map(|i| 0.02 * i as f64).collect();
    let run = |workers: usize| {
        lr_sweep(&factory, workers, &cfg, &lrs, "int4", "rtn", &linreg_inputs).unwrap()
    };
    let serial = run(1);
    assert_eq!(serial.len(), 8);
    assert!(serial.iter().all(|r| !r.diverged));
    let fp = fingerprint(&serial);
    for workers in [4usize, 3, 0] {
        let sharded = run(workers);
        assert_eq!(
            fingerprint(&sharded),
            fp,
            "sweep output differs between --sweep-workers 1 and {workers}"
        );
    }
    // best() agrees with a manual scan of the serial scores
    let best = sweep::best(&serial).unwrap();
    assert!(serial.iter().all(|r| serial[best].score <= r.score));
}

/// Same contract on the transformer LM path: grid points rebuild the
/// token pipeline per point on their worker's engine, so sharding
/// cannot skew the controlled data stream.
#[test]
fn sharded_lm_sweep_is_bit_identical_to_serial() {
    let program = LmProgram::new(
        "lm-sweep-test",
        LmConfig { vocab: 256, d_model: 16, n_layers: 1, n_heads: 2, seq_len: 16 },
        2,
        1,
    )
    .unwrap();
    let factory = NativeFactory::new(
        vec![NativeModel { program: Arc::new(program), opt: OptKind::Adam, steps_per_call: 2 }],
        0,
    );
    let mut cfg = RunConfig::default();
    cfg.name = "lm_sweep_test".into();
    cfg.model = "lm-sweep-test".into();
    cfg.method = "lotion".into();
    cfg.format = "int8".into();
    cfg.eval_formats = vec!["int8".into()];
    cfg.steps = 4;
    cfg.lambda = 10.0;
    cfg.eval_every = 4;
    cfg.schedule = Schedule::Constant;
    cfg.seed = 23;
    let inputs = |_: &dyn Executor,
                  _: &RunConfig|
     -> Result<(Vec<(String, HostTensor)>, DataSource)> {
        let corpus = ZipfMarkovCorpus::generate(20_000, 256, 4, 9);
        let toks = ByteTokenizer::new().encode(&corpus.bytes);
        Ok((vec![], DataSource::Tokens(TokenBatcher::new(toks, 2, 16, 0.1))))
    };
    let lrs = [1e-3, 3e-3, 1e-2];
    let run = |workers: usize| {
        lr_sweep(&factory, workers, &cfg, &lrs, "int8", "rtn", &inputs).unwrap()
    };
    let serial = run(1);
    assert_eq!(serial.len(), 3);
    assert!(serial.iter().all(|r| !r.diverged), "micro LM grid should not diverge");
    assert_eq!(fingerprint(&run(4)), fingerprint(&serial));
}

/// The runner folds results in fixed grid order whatever thread runs
/// each point, labels included, and writes per-point metrics sinks.
#[test]
fn sharded_results_fold_in_grid_order() {
    let factory = linreg_factory();
    let dir = lotion::util::tempdir::TempDir::new();
    let points: Vec<SweepPoint> = (0..6)
        .map(|i| {
            let mut cfg = linreg_base_cfg();
            cfg.lr = 0.02 * (i + 1) as f64;
            SweepPoint::new(format!("p{i}"), cfg)
                .with_metrics_path(dir.path().join(format!("p{i}.jsonl")))
        })
        .collect();
    let results = SweepRunner::new(&factory, 4).run(points, "int4", "rtn", &linreg_inputs).unwrap();
    let labels: Vec<&str> = results.iter().map(|r| r.label.as_str()).collect();
    assert_eq!(labels, vec!["p0", "p1", "p2", "p3", "p4", "p5"]);
    for i in 0..6 {
        assert_eq!(results[i].lr, 0.02 * (i + 1) as f64);
        let text = std::fs::read_to_string(dir.path().join(format!("p{i}.jsonl"))).unwrap();
        assert!(!text.is_empty(), "point {i} wrote no metrics");
    }
}

/// A diverged grid point (unknown model here) scores +inf and flags
/// `diverged` without failing the sweep or the sibling points.
#[test]
fn diverged_point_is_a_data_point_not_a_sweep_failure() {
    let factory = linreg_factory();
    let good = linreg_base_cfg();
    let mut bad = linreg_base_cfg();
    bad.model = "linreg_d9999".into();
    let points = vec![SweepPoint::new("good", good), SweepPoint::new("bad", bad)];
    let results = SweepRunner::new(&factory, 2).run(points, "int4", "rtn", &linreg_inputs).unwrap();
    assert!(!results[0].diverged && results[0].score.is_finite());
    assert!(results[1].diverged && results[1].score.is_infinite());
    assert_eq!(sweep::best(&results), Some(0));
}

/// A spec-expanded grid (DESIGN.md §10) — the `--spec` door into the
/// same runner — is bit-identical at `--sweep-workers 1` and `4`.
#[test]
fn spec_driven_sweep_is_bit_identical_at_any_width() {
    const SRC: &str = "name = spec_ident\n\
                       model = linreg_d256\n\
                       format = int4\n\
                       eval_formats = int4\n\
                       steps = 16\n\
                       eval_every = 16\n\
                       lambda = 1\n\
                       schedule = constant\n\
                       seed = 5\n\
                       grid: method=[qat,lotion] x lr=[0.04,0.08]\n";
    let factory = linreg_factory();
    let models = factory.model_names();
    let plan =
        lotion::spec::plan(SRC, "test.sweep", &RunConfig::default(), models.as_deref()).unwrap();
    assert_eq!(plan.digest, lotion::spec::digest(SRC));
    let run = |workers: usize| {
        SweepRunner::new(&factory, workers)
            .run(plan.points.clone(), &plan.score_format, &plan.score_rounding, &linreg_inputs)
            .unwrap()
    };
    let serial = run(1);
    assert_eq!(serial.len(), 4);
    assert!(serial.iter().all(|r| !r.diverged));
    assert_eq!(fingerprint(&run(4)), fingerprint(&serial));
}

/// Spec expansion produces the *same runs* as hand-built configs: a
/// fig2-shaped method×lr product expands to points whose labels, config
/// digests, and trained results match a hand-rolled grid bit for bit.
#[test]
fn spec_grid_matches_handbuilt_points() {
    const SRC: &str = "name = par\n\
                       model = linreg_d256\n\
                       format = int4\n\
                       eval_formats = int4\n\
                       steps = 16\n\
                       eval_every = 16\n\
                       lambda = 1\n\
                       schedule = constant\n\
                       seed = 5\n\
                       grid: method=[lotion,qat] x lr=[0.04,0.08]\n\
                       when method=lotion: lambda=0.5\n";
    let factory = linreg_factory();
    let plan = lotion::spec::plan(SRC, "test.sweep", &RunConfig::default(), None).unwrap();

    // the hand-built twin of the same grid, method-major
    let mut hand = Vec::new();
    for method in ["lotion", "qat"] {
        for lr in [0.04, 0.08] {
            let mut cfg = linreg_base_cfg();
            cfg.method = method.into();
            cfg.lr = lr;
            cfg.lambda = if method == "lotion" { 0.5 } else { 1.0 };
            let label = format!("{method}_lr{lr}");
            cfg.name = format!("par_{label}");
            hand.push(SweepPoint::new(label, cfg));
        }
    }
    assert_eq!(
        plan.points.iter().map(|p| p.label.as_str()).collect::<Vec<_>>(),
        hand.iter().map(|p| p.label.as_str()).collect::<Vec<_>>()
    );
    for (s, h) in plan.points.iter().zip(&hand) {
        assert_eq!(s.cfg.digest(), h.cfg.digest(), "config mismatch at {}", s.label);
        assert_eq!(s.cfg.name, h.cfg.name);
    }
    let run = |points: Vec<SweepPoint>| {
        SweepRunner::new(&factory, 1).run(points, "int4", "rtn", &linreg_inputs).unwrap()
    };
    assert_eq!(fingerprint(&run(plan.points.clone())), fingerprint(&run(hand)));
}

/// Factories hand every worker its own engine; the trait object is
/// shareable across threads by contract.
#[test]
fn factory_is_shareable_across_threads() {
    let factory = linreg_factory();
    let f: &dyn ExecutorFactory = &factory;
    std::thread::scope(|s| {
        for _ in 0..2 {
            s.spawn(move || {
                let engine = f.spawn().unwrap();
                assert!(engine.manifest().find_init("linreg_d256").is_ok());
            });
        }
    });
}
