//! ISSUE 2 acceptance: training output on the native backend is
//! **bit-identical across thread counts** for a fixed seed. The
//! threaded kernels partition work and derive counter-RNG streams from
//! the problem size alone (`util::pool`, `Rng::stream`), so
//! `--threads 1` and `--threads N` must produce the same parameters,
//! losses and eval values down to the last bit.
//!
//! Model sizes here are chosen to engage the parallel paths
//! (`batch*d` and `d` above `util::pool::PAR_MIN`), not the serial
//! small-tensor fallbacks.

use lotion::config::{RunConfig, Schedule};
use lotion::coordinator::{DataSource, Evaluator, MetricsLogger, Trainer};
use lotion::data::{ByteTokenizer, TokenBatcher, ZipfMarkovCorpus};
use lotion::experiments::common::synth_statics;
use lotion::quant::{QuantFormat, Rounding};
use lotion::runtime::native::{LmConfig, LmProgram, ModelSpec, NativeEngine, NativeModel, OptKind};
use std::sync::Arc;

/// A tensor's exact bit pattern (f32 `==` would paper over NaN/-0.0).
fn bits(t: &lotion::tensor::HostTensor) -> Vec<u32> {
    t.as_f32().iter().map(|v| v.to_bits()).collect()
}

/// One short training run at a given thread count; returns the final
/// parameter bits, the train-loss trace, and a quantized RR eval.
fn run_linreg(method: &str, threads: usize) -> (Vec<Vec<u32>>, Vec<(usize, f64)>, f64) {
    let d = 40_000;
    let engine = NativeEngine::with_models(&[NativeModel::from_spec(
        ModelSpec::LinReg { d, batch: 16 },
        OptKind::Sgd,
        4,
    )])
    .with_threads(threads);
    if threads > 0 {
        assert_eq!(engine.threads(), threads);
    }
    let mut cfg = RunConfig::default();
    cfg.model = format!("linreg_d{d}");
    cfg.method = method.into();
    cfg.format = "int4".into();
    cfg.steps = 8;
    cfg.lr = 0.05;
    cfg.lambda = 1.0;
    cfg.eval_every = 8;
    cfg.schedule = Schedule::Constant;
    cfg.seed = 7;
    let (statics, _, _) = synth_statics(d, 13);
    let mut trainer = Trainer::new(&engine, cfg, statics, DataSource::InGraph).unwrap();
    let mut metrics = MetricsLogger::in_memory();
    for _ in 0..2 {
        trainer.chunk(&mut metrics).unwrap();
    }
    let params = vec![bits(&trainer.state().fetch("w").unwrap())];
    let mut eval = Evaluator::new(3);
    let rr = eval.eval_cast(&trainer, Some(&QuantFormat::int4()), Rounding::Rr).unwrap();
    (params, metrics.train_losses.clone(), rr)
}

#[test]
fn linreg_training_is_bit_identical_across_thread_counts() {
    for method in ["rat", "lotion"] {
        let (p1, l1, e1) = run_linreg(method, 1);
        let (p4, l4, e4) = run_linreg(method, 4);
        let (p3, l3, e3) = run_linreg(method, 3);
        assert_eq!(p1, p4, "{method}: params differ between --threads 1 and 4");
        assert_eq!(p1, p3, "{method}: params differ between --threads 1 and 3");
        for ((s1, v1), (s4, v4)) in l1.iter().zip(&l4) {
            assert_eq!(s1, s4, "{method}: step mismatch");
            assert_eq!(v1.to_bits(), v4.to_bits(), "{method}: loss differs at step {s1}");
        }
        assert_eq!(l1.len(), l3.len());
        assert_eq!(e1.to_bits(), e4.to_bits(), "{method}: RR eval differs");
        assert_eq!(e1.to_bits(), e3.to_bits(), "{method}: RR eval differs");
    }
}

#[test]
fn linear2_training_is_bit_identical_across_thread_counts() {
    let run = |threads: usize| {
        let (d, k) = (12_000, 4);
        let engine = NativeEngine::with_models(&[NativeModel::from_spec(
            ModelSpec::Linear2 { d, k },
            OptKind::Sgd,
            4,
        )])
        .with_threads(threads);
        let mut cfg = RunConfig::default();
        cfg.model = format!("linear2_d{d}_k{k}");
        cfg.method = "lotion".into();
        cfg.format = "int4".into();
        cfg.steps = 8;
        cfg.lr = 0.2;
        cfg.lambda = 1.0;
        cfg.eval_every = 8;
        cfg.schedule = Schedule::Constant;
        cfg.seed = 11;
        let (statics, _, _) = synth_statics(d, 29);
        let mut trainer = Trainer::new(&engine, cfg, statics, DataSource::InGraph).unwrap();
        let mut metrics = MetricsLogger::in_memory();
        for _ in 0..2 {
            trainer.chunk(&mut metrics).unwrap();
        }
        let w1 = bits(&trainer.state().fetch("w1").unwrap());
        let w2 = bits(&trainer.state().fetch("w2").unwrap());
        let mut eval = Evaluator::new(5);
        let fp32 = eval.eval_cast(&trainer, None, Rounding::Rtn).unwrap();
        (w1, w2, metrics.train_losses.clone(), fp32)
    };
    let (w1a, w2a, la, ea) = run(1);
    let (w1b, w2b, lb, eb) = run(4);
    assert_eq!(w1a, w1b, "w1 differs between thread counts");
    assert_eq!(w2a, w2b, "w2 differs between thread counts");
    assert_eq!(la.len(), lb.len());
    for ((sa, va), (sb, vb)) in la.iter().zip(&lb) {
        assert_eq!(sa, sb);
        assert_eq!(va.to_bits(), vb.to_bits(), "loss differs at step {sa}");
    }
    assert_eq!(ea.to_bits(), eb.to_bits(), "fp32 eval differs");
}

/// The transformer LM path (ISSUE 3): training on the interpreter is
/// bit-identical across thread counts — matmul rows, attention heads,
/// norm reductions and loss folds all follow the fixed-chunk contract.
/// A micro config keeps debug-mode runtime low while `m*d*n` work
/// stays above `PAR_MIN`, so the parallel paths engage.
#[test]
fn lm_training_is_bit_identical_across_thread_counts() {
    let run = |threads: usize| {
        let program = LmProgram::new(
            "lm-thread-test",
            LmConfig { vocab: 256, d_model: 32, n_layers: 2, n_heads: 2, seq_len: 32 },
            4,
            2,
        )
        .unwrap();
        let engine = NativeEngine::with_models(&[NativeModel {
            program: Arc::new(program),
            opt: OptKind::Adam,
            steps_per_call: 4,
        }])
        .with_threads(threads);
        let mut cfg = RunConfig::default();
        cfg.model = "lm-thread-test".into();
        cfg.method = "lotion".into();
        cfg.format = "int4".into();
        cfg.steps = 8;
        cfg.lr = 3e-3;
        cfg.lambda = 30.0;
        cfg.eval_every = 8;
        cfg.schedule = Schedule::Constant;
        cfg.seed = 5;
        let corpus = ZipfMarkovCorpus::generate(30_000, 256, 4, 9);
        let toks = ByteTokenizer::new().encode(&corpus.bytes);
        let batcher = TokenBatcher::new(toks, 4, 32, 0.1);
        let mut trainer = Trainer::new(&engine, cfg, vec![], DataSource::Tokens(batcher)).unwrap();
        let mut metrics = MetricsLogger::in_memory();
        for _ in 0..2 {
            trainer.chunk(&mut metrics).unwrap();
        }
        let embed = bits(&trainer.state().fetch("embed").unwrap());
        let wq = bits(&trainer.state().fetch("layer00.attn_wq").unwrap());
        let mut eval = Evaluator::new(7);
        let rr = eval.eval_cast(&trainer, Some(&QuantFormat::int4()), Rounding::Rr).unwrap();
        (embed, wq, metrics.train_losses.clone(), rr)
    };
    let (e1, w1, l1, r1) = run(1);
    let (e4, w4, l4, r4) = run(4);
    assert_eq!(e1, e4, "embed differs between thread counts");
    assert_eq!(w1, w4, "attn_wq differs between thread counts");
    for ((s1, v1), (s4, v4)) in l1.iter().zip(&l4) {
        assert_eq!(s1, s4);
        assert_eq!(v1.to_bits(), v4.to_bits(), "LM loss differs at step {s1}");
    }
    assert_eq!(r1.to_bits(), r4.to_bits(), "LM RR eval differs");
}

/// ISSUE 4 (persistent pool + driver scratch cache): one engine reused
/// across two independent runs must match a fresh engine bit-for-bit.
/// The long-lived pool workers and the cached per-model scratch
/// (activations, gradients, `sqrt_lam` hoist) may carry *capacity*
/// between runs, but never values.
#[test]
fn engine_reuse_across_runs_is_stateless() {
    let run = |engine: &NativeEngine| {
        let mut cfg = RunConfig::default();
        cfg.model = "linreg_d2000".into();
        cfg.method = "lotion".into();
        cfg.format = "int4".into();
        cfg.steps = 8;
        cfg.lr = 0.05;
        cfg.lambda = 1.0;
        cfg.eval_every = 8;
        cfg.schedule = Schedule::Constant;
        cfg.seed = 3;
        let (statics, _, _) = synth_statics(2000, 17);
        let mut trainer = Trainer::new(engine, cfg, statics, DataSource::InGraph).unwrap();
        let mut metrics = MetricsLogger::in_memory();
        for _ in 0..2 {
            trainer.chunk(&mut metrics).unwrap();
        }
        (bits(&trainer.state().fetch("w").unwrap()), metrics.train_losses.clone())
    };
    let mk = || {
        NativeEngine::with_models(&[NativeModel::from_spec(
            ModelSpec::LinReg { d: 2000, batch: 16 },
            OptKind::Sgd,
            4,
        )])
        .with_threads(2)
    };
    let shared = mk();
    let (w1, l1) = run(&shared);
    let (w2, l2) = run(&shared); // same engine: cached scratch + live workers
    let (wf, lf) = run(&mk());
    assert_eq!(w1, w2, "second run on a reused engine diverged");
    assert_eq!(w1, wf, "reused engine diverged from a fresh engine");
    assert_eq!(l1, l2);
    assert_eq!(l1, lf);
}

/// `LOTION_THREADS`-style auto resolution still trains correctly (the
/// CI gate runs the whole suite once at `LOTION_THREADS=1` and once at
/// default; this test exercises the auto path explicitly).
#[test]
fn auto_thread_engine_trains() {
    let engine = NativeEngine::new(); // threads resolved from env/cores
    let mut cfg = RunConfig::default();
    cfg.steps = 16;
    cfg.eval_every = 16;
    cfg.schedule = Schedule::Constant;
    let (statics, _, _) = synth_statics(256, 3);
    let mut trainer = Trainer::new(&engine, cfg, statics, DataSource::InGraph).unwrap();
    let mut metrics = MetricsLogger::in_memory();
    for _ in 0..2 {
        trainer.chunk(&mut metrics).unwrap();
    }
    assert!(metrics.train_losses.iter().all(|(_, l)| l.is_finite()));
    assert!(engine.threads() >= 1);
}
