#!/usr/bin/env bash
# Emit the per-PR BENCH_*.json throughput trajectories (ROADMAP): run
# the micro benches from the repo root so the JSON artifacts land
# there. Default: runtime_micro only (the train-step hot-path rows the
# acceptance gates track); `--all` adds quant_micro and exp_tables.
#
#   scripts/bench.sh          # BENCH_runtime_micro.json at repo root
#   scripts/bench.sh --all    # + BENCH_quant_micro.json, BENCH_exp_tables.json
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo bench --bench runtime_micro =="
cargo bench --bench runtime_micro

if [[ "${1:-}" == "--all" ]]; then
    echo "== cargo bench --bench quant_micro =="
    cargo bench --bench quant_micro
    echo "== cargo bench --bench exp_tables =="
    cargo bench --bench exp_tables
fi

echo "bench.sh: wrote $(ls BENCH_*.json 2>/dev/null | tr '\n' ' ')"
