#!/usr/bin/env bash
# Emit the per-PR BENCH_*.json throughput trajectories (ROADMAP): run
# the micro benches from the repo root so the JSON artifacts land
# there. Default: runtime_micro (train-step + decode + RTN-eval
# hot-path rows), quant_micro (kernel tiers, pack/decode), and the
# serving bench (tokens/s + latency percentiles per decode format);
# `--all` adds exp_tables.
#
#   scripts/bench.sh          # BENCH_runtime_micro.json, BENCH_quant_micro.json,
#                             # BENCH_serve.json
#   scripts/bench.sh --all    # + BENCH_exp_tables.json
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo bench --bench runtime_micro =="
cargo bench --bench runtime_micro

echo "== cargo bench --bench quant_micro =="
cargo bench --bench quant_micro

echo "== lotion-rs bench-serve (BENCH_serve.json) =="
# end-to-end serving throughput: lm-tiny synthetic load across the
# decode-format grid, engine pool + continuous batching (DESIGN.md §8)
cargo build --release
./target/release/lotion-rs bench-serve --backend native \
    --model lm-tiny --engines 2 --max-batch 4 \
    --requests 32 --prompt-len 8 --gen-len 24

if [[ "${1:-}" == "--all" ]]; then
    echo "== cargo bench --bench exp_tables =="
    cargo bench --bench exp_tables
fi

echo "bench.sh: wrote $(ls BENCH_*.json 2>/dev/null | tr '\n' ' ')"
