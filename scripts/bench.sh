#!/usr/bin/env bash
# Emit the per-PR BENCH_*.json throughput trajectories (ROADMAP): run
# the micro benches from the repo root so the JSON artifacts land
# there. Default: runtime_micro (train-step + RTN-eval hot-path rows)
# and quant_micro (kernel tiers, pack/decode); `--all` adds exp_tables.
#
#   scripts/bench.sh          # BENCH_runtime_micro.json, BENCH_quant_micro.json
#   scripts/bench.sh --all    # + BENCH_exp_tables.json
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo bench --bench runtime_micro =="
cargo bench --bench runtime_micro

echo "== cargo bench --bench quant_micro =="
cargo bench --bench quant_micro

if [[ "${1:-}" == "--all" ]]; then
    echo "== cargo bench --bench exp_tables =="
    cargo bench --bench exp_tables
fi

echo "bench.sh: wrote $(ls BENCH_*.json 2>/dev/null | tr '\n' ' ')"
