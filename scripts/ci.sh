#!/usr/bin/env bash
# Tier-1 gate (see README.md / ROADMAP.md): build + test the rust crate
# on default features — no PJRT, no python, no artifacts, fully offline.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q (default threads) =="
cargo test -q

echo "== cargo test -q (LOTION_THREADS=1) =="
# the threaded native backend must be bit-identical serial vs parallel;
# running the whole suite in both modes makes any divergence fail the gate
LOTION_THREADS=1 cargo test -q

echo "== cargo test -q (LOTION_SIMD=scalar) =="
# the runtime-dispatched kernels must be bit-identical scalar vs
# vector (AVX2/NEON); pinning the whole suite to the scalar tier makes
# any fold-order divergence in a vector path fail the gate
LOTION_SIMD=scalar cargo test -q

echo "== threading suite (oversubscribed LOTION_THREADS=16) =="
# more workers than cores shakes out persistent-pool races (lost
# wakeups, stale-epoch claims) that hide at the natural width; the
# threading suite re-checks bit-identity under that pressure
LOTION_THREADS=16 cargo test -q --test threading

echo "== sweep suite (oversubscribed LOTION_SWEEP_WORKERS=8 x LOTION_THREADS=16) =="
# sweep workers multiply by per-engine kernel threads; running the
# sweep determinism suite with both knobs past the core count checks
# that sharded grids stay bit-identical under heavy oversubscription
LOTION_SWEEP_WORKERS=8 LOTION_THREADS=16 cargo test -q --test sweep

echo "== fault-injection lane (LOTION_FAULTS env plan) =="
# crash-safety under a process-wide fault plan (skip with
# LOTION_CI_FAULTS=0): panic@point:3 fires once per test binary at
# sweep grid index 3 and must be absorbed by the default one-retry
# policy on a fresh engine — every suite still passes bit-identical.
# The other entries sit at unreachable ordinals, proving an armed plan
# costs nothing on the sites it never matches.
if [[ "${LOTION_CI_FAULTS:-1}" == "1" ]]; then
    LOTION_FAULTS="panic@point:3,io_err@ckpt_save:999999,kill@step:999999999" \
        cargo test -q --test sweep --test threading --test crash_safety
else
    echo "LOTION_CI_FAULTS=0; skipping fault-injection lane"
fi

echo "== lm-tiny native smoke train (default threads) =="
# the transformer interpreter end-to-end at the CLI surface: a short
# LOTION train on lm-tiny, offline, native backend only
./target/release/lotion-rs train --backend native \
    --set model=lm-tiny --set method=lotion --set quant.format=int4 \
    --set train.steps=8 --set eval.every=8 --set train.lambda=100 \
    --set train.lr=0.003 --out /tmp/lotion_ci_lm

echo "== lm-tiny native smoke train (LOTION_THREADS=1) =="
LOTION_THREADS=1 ./target/release/lotion-rs train --backend native \
    --set model=lm-tiny --set method=lotion --set quant.format=int4 \
    --set train.steps=8 --set eval.every=8 --set train.lambda=100 \
    --set train.lr=0.003 --out /tmp/lotion_ci_lm_t1

echo "== serve smoke lane (lotion bench-serve, lm-tiny) =="
# the serving engine end-to-end at the CLI surface (skip with
# LOTION_CI_SERVE=0): a short continuous-batched generation run on
# lm-tiny, dense + packed formats, default kernels and pinned-scalar —
# exercises decode entries, the engine pool, and BENCH_serve.json
# emission without depending on wall-clock numbers
if [[ "${LOTION_CI_SERVE:-1}" == "1" ]]; then
    ./target/release/lotion-rs bench-serve --backend native \
        --model lm-tiny --formats none,int4,int4@64 \
        --engines 2 --max-batch 2 --requests 6 --prompt-len 6 --gen-len 8 \
        --out /tmp/lotion_ci_serve.json
    LOTION_SIMD=scalar ./target/release/lotion-rs bench-serve --backend native \
        --model lm-tiny --formats int4 \
        --engines 1 --max-batch 2 --requests 4 --prompt-len 6 --gen-len 8 \
        --out /tmp/lotion_ci_serve_scalar.json
else
    echo "LOTION_CI_SERVE=0; skipping serve smoke lane"
fi

echo "== estimator lane (exp est-equiv + exp anneal) =="
# the pluggable-estimator families end-to-end at the CLI surface (skip
# with LOTION_CI_EST=0): the cge-vs-rescaled-QAT equivalence table on
# linreg_d256 and the σ→0 annealing grid on lm-tiny, both through the
# sharded SweepRunner — default kernels and pinned-scalar, scaled down
# via LOTION_EXP_SCALE so the lane stays a smoke test
if [[ "${LOTION_CI_EST:-1}" == "1" ]]; then
    LOTION_EXP_SCALE=0.1 ./target/release/lotion-rs exp est-equiv \
        --backend native --results /tmp/lotion_ci_est
    LOTION_EXP_SCALE=0.1 ./target/release/lotion-rs exp anneal \
        --backend native --sweep-workers 2 --results /tmp/lotion_ci_est
    LOTION_SIMD=scalar LOTION_EXP_SCALE=0.1 ./target/release/lotion-rs exp est-equiv \
        --backend native --results /tmp/lotion_ci_est_scalar
    LOTION_SIMD=scalar LOTION_EXP_SCALE=0.1 ./target/release/lotion-rs exp anneal \
        --backend native --results /tmp/lotion_ci_est_scalar
else
    echo "LOTION_CI_EST=0; skipping estimator lane"
fi

echo "== sweep-spec lane (--spec goldens + lm-tiny grid) =="
# the sweep-spec DSL end-to-end at the CLI surface (skip with
# LOTION_CI_SPEC=0): a dry-run of the in-repo fig2 grid (expansion +
# validation only — spawns nothing), then a tiny lm-tiny spec swept at
# --sweep-workers 1 and 4, whose JSONL results must be byte-identical;
# finally a journaled resume (same spec: every point skipped, same
# bytes out) and a digest-refusal negative test (edited spec + old
# journal must be refused, not silently mixed)
if [[ "${LOTION_CI_SPEC:-1}" == "1" ]]; then
    ./target/release/lotion-rs sweep --backend native \
        --spec examples/fig2.sweep --dry-run
    SPEC_DIR=/tmp/lotion_ci_spec
    rm -rf "$SPEC_DIR" && mkdir -p "$SPEC_DIR"
    cat > "$SPEC_DIR/tiny.sweep" <<'EOF'
name         = ci_tiny
model        = lm-tiny
format       = int4
eval_formats = int4
steps        = 8
eval_every   = 8
lambda       = 100
schedule     = constant
grid: method=[qat,lotion] x lr=[0.002,0.004]
EOF
    for w in 1 4; do
        ./target/release/lotion-rs sweep --backend native \
            --spec "$SPEC_DIR/tiny.sweep" --sweep-workers "$w" \
            --out "$SPEC_DIR/w$w" --sweep-out "$SPEC_DIR/results_w$w.jsonl" \
            --journal "$SPEC_DIR/journal_w$w.jsonl"
    done
    cmp "$SPEC_DIR/results_w1.jsonl" "$SPEC_DIR/results_w4.jsonl"
    ./target/release/lotion-rs sweep --backend native \
        --spec "$SPEC_DIR/tiny.sweep" \
        --out "$SPEC_DIR/w1" --sweep-out "$SPEC_DIR/results_resume.jsonl" \
        --journal "$SPEC_DIR/journal_w1.jsonl" --resume-sweep
    cmp "$SPEC_DIR/results_w1.jsonl" "$SPEC_DIR/results_resume.jsonl"
    sed 's/lambda       = 100/lambda       = 50/' \
        "$SPEC_DIR/tiny.sweep" > "$SPEC_DIR/edited.sweep"
    if ./target/release/lotion-rs sweep --backend native \
        --spec "$SPEC_DIR/edited.sweep" --out "$SPEC_DIR/edited" \
        --journal "$SPEC_DIR/journal_w1.jsonl" --resume-sweep \
        >/dev/null 2>&1; then
        echo "ERROR: an edited spec resumed a stale journal"; exit 1
    fi
else
    echo "LOTION_CI_SPEC=0; skipping sweep-spec lane"
fi

echo "== cargo fmt --check =="
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --check
else
    echo "rustfmt not installed on this toolchain; skipping format check"
fi

echo "== cargo clippy --all-targets -- -D warnings =="
# lint lane (skip with LOTION_CI_CLIPPY=0, or automatically when the
# toolchain has no clippy component — mirrors the rustfmt guard).
# Deny-by-default with explicit, documented exceptions for lints that
# conflict with the crate's established idiom: indexed kernel loops
# (fixed-chunk determinism contract), `RunConfig::default()` +
# field-by-field experiment configs, and arg-rich builder-free APIs.
if [[ "${LOTION_CI_CLIPPY:-1}" == "1" ]] && cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --all-targets -- -D warnings \
        -A unknown_lints \
        -A clippy::needless_range_loop \
        -A clippy::field_reassign_with_default \
        -A clippy::too_many_arguments \
        -A clippy::manual_memcpy \
        -A clippy::type_complexity \
        -A clippy::new_without_default \
        -A clippy::thread_local_initializer_can_be_made_const
else
    echo "clippy unavailable or LOTION_CI_CLIPPY=0; skipping lint lane"
fi

echo "== bench trajectory (scripts/bench.sh) =="
# BENCH_runtime_micro.json at the repo root per PR (ROADMAP); skip with
# LOTION_CI_BENCH=0 when iterating locally
if [[ "${LOTION_CI_BENCH:-1}" == "1" ]]; then
    ./scripts/bench.sh
else
    echo "LOTION_CI_BENCH=0; skipping bench trajectory"
fi

echo "ci.sh: all green"
