#!/usr/bin/env bash
# Tier-1 gate (see README.md / ROADMAP.md): build + test the rust crate
# on default features — no PJRT, no python, no artifacts, fully offline.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q (default threads) =="
cargo test -q

echo "== cargo test -q (LOTION_THREADS=1) =="
# the threaded native backend must be bit-identical serial vs parallel;
# running the whole suite in both modes makes any divergence fail the gate
LOTION_THREADS=1 cargo test -q

echo "== cargo fmt --check =="
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --check
else
    echo "rustfmt not installed on this toolchain; skipping format check"
fi

echo "ci.sh: all green"
