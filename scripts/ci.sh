#!/usr/bin/env bash
# Tier-1 gate (see README.md / ROADMAP.md): build + test the rust crate
# on default features — no PJRT, no python, no artifacts, fully offline.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== cargo fmt --check =="
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --check
else
    echo "rustfmt not installed on this toolchain; skipping format check"
fi

echo "ci.sh: all green"
