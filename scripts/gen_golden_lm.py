#!/usr/bin/env python3
"""Generate rust/tests/golden_lm.json from the python transformer oracle.

The rust native backend's LM interpreter (`runtime/native/transformer.rs`)
promises semantic parity with `python/compile/models/transformer.py`
(forward logits + mean next-token cross-entropy). Parity is
tolerance-based — f32 summation orders differ between XLA and the rust
serial folds — so this script:

1. builds deterministic params/tokens from an integer-hash formula the
   rust test reproduces exactly (no 1.5 MB of weights in the golden
   file, and no dependence on cross-language PRNG parity);
2. evaluates the *jax* oracle to produce golden losses + sampled logit
   fingerprints;
3. runs a pure-numpy transliteration of the rust interpreter against
   the oracle, so a drift in either side is caught at generation time
   and the committed tolerances have measured headroom.

Usage:  python3 scripts/gen_golden_lm.py
"""

from __future__ import annotations

import json
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "python"))

from compile.models import transformer  # noqa: E402

OUT = os.path.join(os.path.dirname(__file__), "..", "rust", "tests", "golden_lm.json")

M64 = (1 << 64) - 1
KNUTH = 0x9E3779B97F4A7C15


def mix64(z: int) -> int:
    """SplitMix64 finalizer — must match util::rng::mix64 bit-for-bit."""
    z &= M64
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & M64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & M64
    return (z ^ (z >> 31)) & M64


def unit(h: int) -> float:
    """Map a 64-bit hash to [-1, 1) exactly as the rust test does."""
    return (h >> 11) / float(1 << 52) - 1.0


def golden_params(cfg: transformer.LMConfig) -> dict:
    """Deterministic non-degenerate weights from the hash formula."""
    shapes = {
        k: v.shape
        for k, v in transformer.init(
            __import__("jax").random.PRNGKey(0), cfg
        ).items()
    }
    params = {}
    for pi, name in enumerate(sorted(shapes)):
        n = int(np.prod(shapes[name]))
        base = ((pi + 1) * KNUTH) & M64
        vals = np.array([unit(mix64(base + j)) for j in range(n)], dtype=np.float64)
        if name.startswith("layer") and "norm" in name or name == "norm_final":
            flat = (1.0 + 0.1 * vals).astype(np.float32)
        else:
            flat = (0.05 * vals).astype(np.float32)
        params[name] = flat.reshape(shapes[name])
    return params


def golden_tokens(tag: int, batch: int, t1: int, vocab: int) -> np.ndarray:
    base = ((tag + 1) * 0xC0FFEE12345678) & M64
    toks = [mix64(base + j) % vocab for j in range(batch * t1)]
    return np.array(toks, dtype=np.int32).reshape(batch, t1)


def fingerprint_positions(tag: int, rows: int, vocab: int, n: int = 48):
    out = []
    for idx in range(n):
        h = mix64(((tag + 7) * 31 + idx) & M64)
        out.append((h % rows, (h >> 32) % vocab))
    return out


# --- numpy transliteration of rust/src/runtime/native/transformer.rs ---


def rust_forward(params: dict, tokens: np.ndarray, cfg: transformer.LMConfig):
    """Forward pass mirroring the rust kernels (f32 throughout; numpy's
    vectorized sums replace the rust serial folds, which is exactly the
    class of difference the committed tolerances must absorb)."""
    b, t = tokens.shape
    d, nh, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    half = hd // 2
    f32 = np.float32
    h = params["embed"][tokens].astype(f32)  # [B,T,D]

    # rope tables as rust computes them: f64 trig, cast to f32
    j = np.arange(half, dtype=np.float64)
    freqs = 10000.0 ** (-j / half)
    ang = np.arange(t, dtype=np.float64)[:, None] * freqs[None, :]
    cos = np.cos(ang).astype(f32)[None, :, None, :]
    sin = np.sin(ang).astype(f32)[None, :, None, :]

    def rms(x, g):
        r = 1.0 / np.sqrt(np.mean(x * x, axis=-1, keepdims=True) + f32(1e-6))
        return (x * g * r).astype(f32)

    def rope(x):
        x = x.reshape(b, t, nh, hd)
        x1, x2 = x[..., :half], x[..., half:]
        o = np.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
        return o.astype(f32).reshape(b, t, d)

    for l in range(cfg.n_layers):
        pre = f"layer{l:02d}."
        xn = rms(h, params[pre + "norm_attn"])
        q = rope(xn @ params[pre + "attn_wq"])
        k = rope(xn @ params[pre + "attn_wk"])
        v = (xn @ params[pre + "attn_wv"]).reshape(b, t, nh, hd)
        qh = q.reshape(b, t, nh, hd)
        kh = k.reshape(b, t, nh, hd)
        att = np.einsum("bthd,bshd->bhts", qh, kh).astype(f32) * f32(
            1.0 / np.sqrt(np.float32(hd))
        )
        mask = np.tril(np.ones((t, t), dtype=bool))
        att = np.where(mask[None, None], att, f32(-np.inf))
        att = att - att.max(axis=-1, keepdims=True)
        p = np.exp(att, dtype=f32)
        p = np.where(mask[None, None], p, f32(0.0))
        p = (p / p.sum(axis=-1, keepdims=True)).astype(f32)
        o = np.einsum("bhts,bshd->bthd", p, v).astype(f32).reshape(b, t, d)
        h = h + o @ params[pre + "attn_wo"]
        xn = rms(h, params[pre + "norm_mlp"])
        g = (xn @ params[pre + "mlp_wgate"]).astype(f32)
        sil = g / (1.0 + np.exp(-g, dtype=f32))
        u = (xn @ params[pre + "mlp_wup"]).astype(f32)
        h = h + (sil * u) @ params[pre + "mlp_wdown"]
        h = h.astype(f32)
    h = rms(h, params["norm_final"])
    return (h @ params["lm_head"]).astype(f32)


def rust_loss(params, batch, cfg):
    tokens, targets = batch[:, :-1], batch[:, 1:]
    logits = rust_forward(params, tokens, cfg)
    mx = logits.max(axis=-1, keepdims=True)
    z = np.exp(logits - mx, dtype=np.float32).sum(axis=-1)
    logz = mx[..., 0] + np.log(z)
    gold = np.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return float(np.mean((logz - gold).astype(np.float64)))


def main():
    import jax.numpy as jnp

    cases = []
    specs = [
        ("lm-tiny", transformer.PRESETS["lm-tiny"], 8, 0),
        ("lm-tiny", transformer.PRESETS["lm-tiny"], 8, 1),
        (
            "lm-micro-golden",
            transformer.LMConfig(
                "lm-micro-golden", vocab=64, d_model=32, n_layers=2, n_heads=2, seq_len=16
            ),
            2,
            2,
        ),
    ]
    worst_loss, worst_logit = 0.0, 0.0
    for name, cfg, batch, tag in specs:
        params = golden_params(cfg)
        batch_toks = golden_tokens(tag, batch, cfg.seq_len + 1, cfg.vocab)
        jparams = {k: jnp.asarray(v) for k, v in params.items()}
        jloss = float(transformer.loss(jparams, jnp.asarray(batch_toks), cfg))
        jlogits = np.asarray(
            transformer.forward(jparams, jnp.asarray(batch_toks[:, :-1]), cfg)
        ).reshape(-1, cfg.vocab)

        # generation-time cross-check: the rust-algorithm transliteration
        nloss = rust_loss(params, batch_toks, cfg)
        nlogits = rust_forward(params, batch_toks[:, :-1], cfg).reshape(-1, cfg.vocab)
        dl = abs(nloss - jloss)
        dg = float(np.max(np.abs(nlogits - jlogits)))
        worst_loss, worst_logit = max(worst_loss, dl), max(worst_logit, dg)
        print(f"{name}/tag{tag}: jax loss {jloss:.6f}  translit dloss={dl:.2e} dlogit={dg:.2e}")
        assert dl < 2e-4, f"loss drift {dl}"
        assert dg < 2e-3, f"logit drift {dg}"

        rows = batch * cfg.seq_len
        fps = [
            [int(r), int(c), float(jlogits[r, c])]
            for r, c in fingerprint_positions(tag, rows, cfg.vocab)
        ]
        cases.append(
            {
                "name": name,
                "tag": tag,
                "config": {
                    "vocab": cfg.vocab,
                    "d_model": cfg.d_model,
                    "n_layers": cfg.n_layers,
                    "n_heads": cfg.n_heads,
                    "seq_len": cfg.seq_len,
                },
                "batch": batch,
                "loss": jloss,
                "fingerprints": fps,
            }
        )

    with open(OUT, "w") as f:
        json.dump({"cases": cases}, f, indent=1)
    print(f"wrote {OUT} ({len(cases)} cases); worst translit diffs: "
          f"loss {worst_loss:.2e}, logit {worst_logit:.2e}")


if __name__ == "__main__":
    main()
