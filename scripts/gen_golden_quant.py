#!/usr/bin/env python3
"""Generate rust/tests/golden_quant.json from the python quant oracle.

The rust `quant` substrate promises bit-parity with
`python/compile/kernels/ref.py` (scales, RTN casts, sigma^2, the LOTION
penalty). This script evaluates the python oracle over a deterministic
case grid and writes the goldens the `parity.rs` integration test
checks. It also runs a pure-numpy transliteration of the *rust*
algorithms against the oracle so a drift in either side is caught at
generation time, before it ever reaches CI.

Usage:  python3 scripts/gen_golden_quant.py
"""

from __future__ import annotations

import json
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "python"))

from compile.kernels import ref  # noqa: E402
from compile.kernels.common import make_format  # noqa: E402

OUT = os.path.join(os.path.dirname(__file__), "..", "rust", "tests", "golden_quant.json")

FP4_LEVELS = np.array(
    [-6.0, -4.0, -3.0, -2.0, -1.5, -1.0, -0.5, 0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0],
    dtype=np.float32,
)


# --- numpy transliteration of rust/src/quant (generation-time check) ---


def rust_block_ranges(n: int, block_size: int):
    bs = max(n, 1) if block_size == 0 else block_size
    return [(b * bs, min((b + 1) * bs, n)) for b in range(-(-n // bs))]


def rust_block_scales(w: np.ndarray, fmt) -> np.ndarray:
    out = []
    for s, e in rust_block_ranges(len(w), fmt.block_size):
        amax = np.max(np.abs(w[s:e])) if e > s else 0.0
        out.append(np.float32(amax) / np.float32(fmt.qmax) if amax > 0 else np.float32(1.0))
    return np.array(out, dtype=np.float32)


def rust_bracket(z: np.float32, fmt):
    if fmt.uniform:
        l = np.floor(z)
        return (z, z) if l == z else (l, l + 1)
    lo, up = -np.inf, np.inf
    for lev in FP4_LEVELS:
        if lev <= z and lev > lo:
            lo = lev
        if lev >= z and lev < up:
            up = lev
    return np.float32(lo), np.float32(up)


def rust_rtn_one(z: np.float32, fmt) -> np.float32:
    if fmt.uniform:
        # rust f32::round_ties_even == np.round (banker's rounding)
        return np.clip(np.round(z), -fmt.qmax, fmt.qmax)
    lo, up = rust_bracket(z, fmt)
    mid = np.float32(0.5) * (lo + up)
    return up if z > mid else lo


def rust_cast_rtn(w: np.ndarray, fmt) -> np.ndarray:
    scales = rust_block_scales(w, fmt)
    out = w.copy()
    for bi, (s, e) in enumerate(rust_block_ranges(len(w), fmt.block_size)):
        sb = scales[bi]
        for i in range(s, e):
            out[i] = rust_rtn_one(np.float32(w[i] / sb), fmt) * sb
    return out


def rust_sigma2(w: np.ndarray, fmt) -> np.ndarray:
    scales = rust_block_scales(w, fmt)
    out = np.zeros_like(w)
    for bi, (s, e) in enumerate(rust_block_ranges(len(w), fmt.block_size)):
        sb = scales[bi]
        for i in range(s, e):
            z = np.float32(w[i] / sb)
            lo, up = rust_bracket(z, fmt)
            out[i] = sb * sb * (up - z) * (z - lo)
    return out


def rust_penalty(w: np.ndarray, fisher: np.ndarray, fmt) -> float:
    s2 = rust_sigma2(w, fmt)
    return float(np.sum(0.5 * s2.astype(np.float64) * fisher.astype(np.float64)))


# --- case grid ---------------------------------------------------------


def cases():
    rng = np.random.default_rng(20260729)
    grid = [
        ("int4", 0, 48),
        ("int4", 16, 48),
        ("int4", 64, 96),   # partial final block (96 = 1.5 * 64)
        ("int8", 0, 48),
        ("int8", 16, 40),   # partial final block
        ("int8", 64, 64),
        ("fp4", 0, 48),
        ("fp4", 16, 48),
        ("fp4", 64, 80),    # partial final block
    ]
    out = []
    for fmt_name, block, n in grid:
        for scale in (0.08, 2.5):
            w = (rng.standard_normal(n) * scale).astype(np.float32)
            fisher = np.abs(rng.standard_normal(n)).astype(np.float32)
            out.append((fmt_name, block, w, fisher))
    # an all-zero block exercises the s = 1 fallback
    w = np.zeros(32, dtype=np.float32)
    w[16:] = (rng.standard_normal(16) * 0.5).astype(np.float32)
    fisher = np.ones(32, dtype=np.float32)
    out.append(("int4", 16, w, fisher))
    return out


def main() -> None:
    docs = []
    for fmt_name, block, w, fisher in cases():
        fmt = make_format(fmt_name, block)
        scales = np.asarray(ref.block_scales_ref(w, fmt))
        rtn = np.asarray(ref.fake_quant_ref(w, fmt))
        s2 = np.asarray(ref.sigma2_ref(w, fmt))
        pen = float(np.asarray(ref.lotion_penalty_ref(w, fisher, fmt)))

        # cross-check the rust transliteration against the oracle
        np.testing.assert_allclose(rust_block_scales(w, fmt), scales, rtol=1e-7)
        np.testing.assert_allclose(rust_cast_rtn(w, fmt), rtn, rtol=1e-6, atol=1e-7)
        np.testing.assert_allclose(rust_sigma2(w, fmt), s2, rtol=2e-5, atol=1e-9)
        assert abs(rust_penalty(w, fisher, fmt) - pen) <= 1e-5 * max(abs(pen), 1e-9), (
            fmt_name,
            block,
            rust_penalty(w, fisher, fmt),
            pen,
        )

        docs.append(
            {
                "format": fmt_name,
                "block": block,
                "w": [float(v) for v in w],
                "fisher": [float(v) for v in fisher],
                "scales": [float(v) for v in scales],
                "rtn": [float(v) for v in rtn],
                "sigma2": [float(v) for v in s2],
                "penalty": pen,
            }
        )
    with open(OUT, "w") as f:
        json.dump(docs, f)
    print(f"wrote {len(docs)} cases -> {OUT}")


if __name__ == "__main__":
    main()
